"""Beyond-paper §Perf flags must not change the math — only the schedule.

Each optimization is gated by a ModelConfig flag (baseline = all off); loss
and gradients must match the baseline on reduced configs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model

FLAG_SETS = [
    {"attn_tp_pad": True},
    {"attn_remat": True},
    {"fused_xent": True},
    {"attn_bf16_probs": True},
    {"attn_tp_pad": True, "attn_remat": True, "fused_xent": True,
     "seq_parallel": True},
]


def _grads_match(cfg0, cfg1, rtol=2e-3, atol=2e-5, seq=32):
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, seq), 0,
                             cfg0.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    l0, _ = m0.loss_fn(params, batch)
    l1, _ = m1.loss_fn(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    g0 = jax.grad(lambda p: m0.loss_fn(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss_fn(p, batch)[0])(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=rtol, atol=atol), g0, g1)


@pytest.mark.parametrize("flags", FLAG_SETS,
                         ids=lambda f: "+".join(sorted(f)))
def test_dense_flags_preserve_numerics(flags):
    cfg0 = dataclasses.replace(
        reduced(get_arch("qwen2-7b"), n_layers=2, d_model=128, vocab=128),
        param_dtype="float32")
    _grads_match(cfg0, dataclasses.replace(cfg0, **flags))


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "llama4-maverick-400b-a17b"])
def test_moe_grouped_dispatch_preserves_numerics(arch):
    cfg0 = dataclasses.replace(reduced(get_arch(arch), vocab=128),
                               param_dtype="float32")
    _grads_match(cfg0, dataclasses.replace(cfg0, moe_group_tokens=True))


def test_ssm_seq_parallel_flag_noop_off_mesh():
    # without active sharding rules the flags must be exact no-ops
    cfg0 = dataclasses.replace(reduced(get_arch("mamba2-370m"), vocab=128),
                               param_dtype="float32")
    _grads_match(cfg0, dataclasses.replace(cfg0, seq_parallel=True),
                 rtol=1e-6, atol=1e-7)
