"""Trace subsystem: schema round-trips (JSON + Chrome export) are lossless,
replay is deterministic and reproduces the measured sync schedule exactly
for fixed_h and adaptive runs, and the what-if sweeps produce monotone
curves."""
import dataclasses
import json

import pytest

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.core import comm
from repro.trace import SPAN_KINDS, Span, Trace, TraceRecorder
from repro.trace.chrome import from_chrome, to_chrome
from repro.trace.replay import (ReplayKnobs, replay, sweep_H, sweep_codecs,
                                sweep_workers, validate)

SHAPE = ShapeConfig(name="trace", seq_len=32, global_batch=8, kind="train")
STEPS = 16


def _traced_run(policy, tmpdir, **sync_kw):
    from repro.launch.train import train_loop
    cfg = reduced(get_arch("biglstm"), vocab=128)
    sync = SyncConfig(policy=policy, **sync_kw)
    opt = OptimizerConfig.from_sync(sync, name="local_adaalter", lr=0.5,
                                    H=3, warmup_steps=5)
    path = str(tmpdir / f"trace_{policy}.json")
    res = train_loop(cfg, SHAPE, opt, steps=STEPS, verbose=False,
                     trace_out=path)
    return res, Trace.load(path)


@pytest.fixture(scope="module")
def fixed_h_run(tmp_path_factory):
    return _traced_run("fixed_h", tmp_path_factory.mktemp("fixed"))


@pytest.fixture(scope="module")
def adaptive_run(tmp_path_factory):
    return _traced_run("adaptive", tmp_path_factory.mktemp("adaptive"),
                       threshold=0.002, h_min=2, h_max=6)


# --------------------------------------------------------------------------- #
# schema
# --------------------------------------------------------------------------- #
def test_recorder_rejects_unknown_span_kind():
    rec = TraceRecorder()
    with pytest.raises(ValueError, match="unknown span kind"):
        rec.add("not_a_kind", t0=0.0, dur=1.0)


def test_trace_json_roundtrip_lossless(fixed_h_run):
    _, trace = fixed_h_run
    d = trace.to_dict()
    again = Trace.from_dict(json.loads(json.dumps(d)))
    assert again.to_dict() == d


def test_trace_version_gate():
    with pytest.raises(ValueError, match="schema version"):
        Trace.from_dict({"version": 999, "meta": {}, "spans": []})


def test_span_stream_shape(fixed_h_run):
    res, trace = fixed_h_run
    assert all(s.name in SPAN_KINDS for s in trace.spans)
    steps = trace.by_name("local_step")
    # one step span per worker per step
    assert len(steps) == res.n_workers * STEPS
    # the engine's actual decisions ride the spans
    synced = sorted({s.step for s in steps if s.args["synced"]})
    assert synced == res.sync_steps
    # modeled round costs attached on every sync round
    colls = trace.by_name("collective")
    assert sorted({s.step for s in colls}) == res.sync_steps
    assert all(s.modeled and s.args["wire_bytes"] > 0 for s in colls)
    # spans share one rebased monotonic clock
    assert min(s.t0 for s in trace.spans) >= 0.0
    assert trace.meta["clock"] == "perf_counter"


def test_adaptive_trace_records_drift_stream(adaptive_run):
    _, trace = adaptive_run
    drifts = [s.args["drift"] for s in trace.by_name("local_step")]
    assert any(d > 0 for d in drifts)


# --------------------------------------------------------------------------- #
# Chrome export
# --------------------------------------------------------------------------- #
def test_chrome_roundtrip_lossless(adaptive_run):
    _, trace = adaptive_run
    doc = to_chrome(trace)
    # the export itself must be JSON-serializable
    again = from_chrome(json.loads(json.dumps(doc)))
    assert again.to_dict() == trace.to_dict()


def test_chrome_has_rows_and_flow_arrows(fixed_h_run):
    res, trace = fixed_h_run
    evs = to_chrome(trace)["traceEvents"]
    names = {e["args"]["name"] for e in evs if e.get("ph") == "M"
             and e["name"] == "process_name"}
    assert names == {f"worker {w}" for w in trace.workers}
    flows = [e for e in evs if e.get("ph") in ("s", "f")]
    # one start + one finish arrow per worker per sync round
    assert len(flows) == 2 * res.n_workers * res.sync_count


# --------------------------------------------------------------------------- #
# replay
# --------------------------------------------------------------------------- #
def test_replay_deterministic_bit_identical(adaptive_run):
    _, trace = adaptive_run
    knobs = ReplayKnobs(fabric=comm.FabricModel(), n_workers=16, codec="int8")
    a, b = replay(trace, knobs), replay(trace, knobs)
    assert a.to_dict() == b.to_dict()
    base_a, base_b = replay(trace), replay(trace)
    assert base_a.to_dict() == base_b.to_dict()


@pytest.mark.parametrize("which", ["fixed_h", "adaptive"])
def test_replayed_schedule_equals_measured(which, fixed_h_run, adaptive_run):
    res, trace = fixed_h_run if which == "fixed_h" else adaptive_run
    r = replay(trace)
    assert r.sync_count == res.sync_count
    assert r.sync_steps == res.sync_steps


@pytest.mark.parametrize("which", ["fixed_h", "adaptive"])
def test_validate_gate_passes(which, fixed_h_run, adaptive_run):
    _, trace = fixed_h_run if which == "fixed_h" else adaptive_run
    # The baseline replay cancels exactly UNLESS scheduling noise makes the
    # warm sync mean dip below the warm local mean (the >= 0 overhead
    # clamp) — a few-sample-mean effect on a loaded CI box — so the
    # bit-exactness claim is pinned on the hand-built traces below, and the
    # live-run gate runs at the stated default tolerance.
    v = validate(trace)
    assert v["ok"], v


def test_replay_h_knob_changes_schedule(fixed_h_run):
    _, trace = fixed_h_run
    every = replay(trace, ReplayKnobs(H=1, sync_policy="fixed_h"))
    assert every.sync_count == STEPS
    never = replay(trace, ReplayKnobs(H=STEPS + 1, sync_policy="fixed_h"))
    assert never.sync_count == 0


def test_replay_h_knob_on_adaptive_trace_switches_to_fixed_h(adaptive_run):
    # a bare H knob must not be silently swallowed by the recorded
    # adaptive policy (where H only seeds the h_max default)
    _, trace = adaptive_run
    every = replay(trace, ReplayKnobs(H=1))
    assert every.policy == "fixed_h"
    assert every.sync_count == STEPS


def test_knobs_report_flat_false(fixed_h_run):
    _, trace = fixed_h_run
    r = replay(trace, ReplayKnobs(flat=False))
    assert r.knobs == {"flat": False}


def test_nonfinite_meta_survives_strict_json(tmp_path):
    # --sync-threshold inf is a supported degenerate; Perfetto rejects the
    # bare Infinity literal, so save/export must strict-JSON encode it
    trace = _hand_trace()
    trace.meta["sync"]["threshold"] = float("inf")
    p = tmp_path / "inf.trace.json"
    trace.save(str(p))
    json.loads(p.read_text(), parse_constant=lambda s: pytest.fail(
        f"non-RFC JSON literal {s} in saved trace"))
    again = Trace.load(str(p))
    assert again.meta["sync"]["threshold"] == float("inf")
    doc = json.loads(json.dumps(to_chrome(trace)), parse_constant=lambda s:
                     pytest.fail(f"non-RFC JSON literal {s} in export"))
    assert from_chrome(doc).meta["sync"]["threshold"] == float("inf")


def test_span_context_manager_records_on_exception():
    rec = TraceRecorder()
    with pytest.raises(RuntimeError):
        with rec.span("eval", step=3, tag="x"):
            raise RuntimeError("boom")
    (s,) = rec.spans
    assert s.name == "eval" and s.step == 3 and s.args["tag"] == "x"
    assert s.dur >= 0.0


def test_replay_threshold_knob_uses_drift_stream(adaptive_run):
    res, trace = adaptive_run
    # threshold 0 -> sync every h_min steps; inf -> every h_max
    lo = replay(trace, ReplayKnobs(sync_threshold=0.0))
    hi = replay(trace, ReplayKnobs(sync_threshold=float("inf")))
    assert lo.sync_count >= hi.sync_count
    assert lo.sync_count >= res.sync_count >= hi.sync_count


def test_replay_baseline_has_no_wire_time(fixed_h_run):
    _, trace = fixed_h_run
    base = replay(trace)
    assert base.comm_s == 0.0 and base.comm_fraction == 0.0
    with_fabric = replay(trace, ReplayKnobs(fabric=comm.FabricModel(),
                                            n_workers=8))
    assert with_fabric.comm_s > 0.0
    assert with_fabric.wall_s > base.wall_s


def test_bw_scale_knob_slows_the_wire(fixed_h_run):
    _, trace = fixed_h_run
    fast = replay(trace, ReplayKnobs(bw_scale=1.0, n_workers=8))
    slow = replay(trace, ReplayKnobs(bw_scale=0.1, n_workers=8))
    assert slow.comm_s > fast.comm_s
    # bw_scale composes with an explicit fabric instead of being ignored
    fab = comm.FabricModel()
    both = replay(trace, ReplayKnobs(fabric=fab, bw_scale=0.1, n_workers=8))
    only = replay(trace, ReplayKnobs(fabric=fab, n_workers=8))
    assert both.comm_s > only.comm_s


def test_flat_knob_reduces_collective_count(fixed_h_run):
    _, trace = fixed_h_run
    per_leaf = replay(trace, ReplayKnobs(fabric=comm.FabricModel(),
                                         n_workers=8, flat=False))
    flat = replay(trace, ReplayKnobs(fabric=comm.FabricModel(),
                                     n_workers=8, flat=True))
    assert flat.n_collectives_per_round == 1
    assert per_leaf.n_collectives_per_round > 1
    assert flat.comm_s < per_leaf.comm_s


# --------------------------------------------------------------------------- #
# sweeps (the paper's curve shapes)
# --------------------------------------------------------------------------- #
def test_comm_fraction_monotone_in_workers(adaptive_run):
    _, trace = adaptive_run
    rows = sweep_workers(trace, (1, 2, 4, 8, 16, 32))
    fracs = [r["comm_fraction"] for r in rows]
    assert all(b >= a for a, b in zip(fracs, fracs[1:]))
    assert fracs[0] == 0.0          # one worker: nothing to all-reduce


def test_wall_monotone_in_H(fixed_h_run):
    _, trace = fixed_h_run
    rows = sweep_H(trace, (1, 2, 4, 8, 16))
    walls = [r["wall_s"] for r in rows]
    assert all(b <= a for a, b in zip(walls, walls[1:]))
    assert rows[-1]["speedup_vs_first"] >= 1.0


def test_codec_sweep_orders_wire_volume(fixed_h_run):
    _, trace = fixed_h_run
    rows = {r["codec"]: r for r in sweep_codecs(trace)}
    assert rows["fp32"]["round_wire_bytes"] > rows["bf16"]["round_wire_bytes"]
    assert rows["bf16"]["round_wire_bytes"] > rows["int8"]["round_wire_bytes"]
    assert rows["fp32"]["comm_s"] >= rows["bf16"]["comm_s"] >= \
        rows["int8"]["comm_s"]


# --------------------------------------------------------------------------- #
# replay math on a hand-built trace (no jax run)
# --------------------------------------------------------------------------- #
def _hand_trace():
    rec = TraceRecorder(meta={
        "kind": "train", "algorithm": "local_adaalter", "n_params": 1000,
        "n_workers": 2, "steps": 6, "start_step": 0, "H": 3,
        "is_local": True, "flat": False,
        "sync": {"policy": "fixed_h", "threshold": 0.0, "h_min": 1,
                 "h_max": 12, "compression": "", "block": 256},
        "n_payload_leaves": 4,
        "fabric": dataclasses.asdict(comm.FabricModel()),
        "clock": "perf_counter",
        "sync_state0": {"since": 0, "drift": 0.0},
    })
    t = 0.0
    for step in range(6):
        synced = (step + 1) % 3 == 0
        dur = 3.0 if synced else 1.0          # sync overhead = 2.0
        for w in range(2):
            rec.add("local_step", worker=w, step=step, t0=t, dur=dur,
                    synced=synced, loss=1.0, drift=0.5)
        t += dur
    trace = rec.freeze()
    trace.meta["measured"] = {"wall_s": t, "sync_count": 2,
                              "sync_steps": [2, 5]}
    return trace


def test_replay_rejects_dryrun_traces():
    trace = _hand_trace()
    trace.meta["kind"] = "dryrun"
    with pytest.raises(ValueError, match="train trace"):
        replay(trace)
    with pytest.raises(ValueError, match="train trace"):
        validate(trace)


def test_hand_trace_baseline_is_exact():
    trace = _hand_trace()
    r = replay(trace)
    assert r.wall_s == pytest.approx(10.0)      # 4x1 + 2x3
    assert r.compute_s == pytest.approx(6.0)
    assert r.sync_overhead_s == pytest.approx(4.0)
    assert r.sync_steps == [2, 5]
    assert validate(trace)["ok"]


def test_hand_trace_h_knob_arithmetic():
    trace = _hand_trace()
    r = replay(trace, ReplayKnobs(H=6))
    # one round instead of two: 6 x 1.0 compute + 1 x 2.0 overhead
    assert r.sync_steps == [5]
    assert r.wall_s == pytest.approx(8.0)


def test_warm_estimates_exclude_compile_walls():
    # step 0 and the first sync step carry jit-compile walls; a what-if
    # schedule must charge replayed rounds the steady-state cost, and the
    # validate gate must hold against the equally warm-corrected wall
    rec = TraceRecorder(meta=_hand_trace().meta)
    durs = [(0, False, 5.0), (1, False, 1.0), (2, True, 7.0),
            (3, False, 1.0), (4, False, 1.0), (5, True, 3.0)]
    t = 0.0
    for step, synced, dur in durs:
        for w in range(2):
            rec.add("local_step", worker=w, step=step, t0=t, dur=dur,
                    synced=synced, loss=1.0, drift=0.5)
        t += dur
    trace = rec.freeze()
    trace.meta["measured"] = {"wall_s": t, "sync_count": 2,
                              "sync_steps": [2, 5]}
    # warm: compute 1.0/step, sync overhead 3.0 - 1.0 = 2.0 — compiles out
    r = replay(trace, ReplayKnobs(H=6))
    assert r.wall_s == pytest.approx(8.0)        # 6x1 + 1x2, no compile
    v = validate(trace)
    assert v["ok"] and v["ratio"] == pytest.approx(1.0)
    assert v["measured_warm_wall_s"] == pytest.approx(10.0)
    assert v["measured_span_wall_s"] == pytest.approx(18.0)


def test_all_sync_trace_gate_excludes_compile():
    # H=1: every step syncs, so there are NO local samples — the compute
    # estimate must come from the warm sync walls, not the raw mean that
    # folds step 0's jit-compile wall into every replayed step
    rec = TraceRecorder(meta={**_hand_trace().meta, "H": 1})
    t = 0.0
    for step in range(12):
        dur = 2.0 if step == 0 else 0.05       # step 0 = compile
        for w in range(2):
            rec.add("local_step", worker=w, step=step, t0=t, dur=dur,
                    synced=True, loss=1.0, drift=0.0)
        t += dur
    trace = rec.freeze()
    trace.meta["measured"] = {"wall_s": t, "sync_count": 12,
                              "sync_steps": list(range(12))}
    v = validate(trace)
    assert v["ok"], v
    assert v["ratio"] == pytest.approx(1.0)
    assert v["measured_warm_wall_s"] == pytest.approx(12 * 0.05)


def test_hand_trace_wire_term_matches_alpha_beta():
    trace = _hand_trace()
    fabric = comm.FabricModel()
    r = replay(trace, ReplayKnobs(fabric=fabric, n_workers=8))
    per_round = comm.sync_payload_bytes("local_adaalter", 1000)
    expect = fabric.collective_time(per_round, 8, 8)    # 4 leaves x 2
    assert r.comm_s == pytest.approx(2 * expect)


# --------------------------------------------------------------------------- #
# HLO-priced sync overhead (PR 10)
# --------------------------------------------------------------------------- #
def _with_hlo(trace, local_s, sync_s):
    trace.meta["hlo_cost"] = {
        "local_step": {"optimal_s": local_s, "flops": 1.0, "bytes": 1.0,
                       "regions": []},
        "sync_step": {"optimal_s": sync_s, "flops": 1.0, "bytes": 1.0,
                      "regions": []},
        "hw": {"peak_flops": 1.0, "hbm_bw": 1.0}}
    return trace


def test_hlo_priced_overhead_exact_arithmetic():
    # sync/local optimal ratio 1.5 -> rel overhead 0.5, anchored to the
    # warm local mean (1.0 s): each round costs 0.5 s instead of the
    # measured 2.0 s — the cost model's number, not the warm-mean diff
    trace = _with_hlo(_hand_trace(), local_s=2e-3, sync_s=3e-3)
    r = replay(trace)
    assert r.priced_from == "hlo_regions"
    assert r.compute_s == pytest.approx(6.0)
    assert r.sync_overhead_s == pytest.approx(2 * 0.5 * 1.0)
    assert r.wall_s == pytest.approx(7.0)
    v = validate(trace)
    assert v["priced_from"] == "hlo_regions"
    # measured warm wall is 10.0; the gate now genuinely tests the model
    assert v["ratio"] == pytest.approx(7.0 / 10.0)


def test_hlo_ratio_below_one_clamps_to_zero_overhead():
    trace = _with_hlo(_hand_trace(), local_s=3e-3, sync_s=2e-3)
    r = replay(trace)
    assert r.priced_from == "hlo_regions"
    assert r.sync_overhead_s == 0.0


def test_hlo_pricing_skipped_on_all_sync_trace():
    # H=1: compute_est already IS the warm sync mean — adding a ratio-
    # priced extra on top would double-charge every round
    rec = TraceRecorder(meta={**_hand_trace().meta, "H": 1})
    t = 0.0
    for step in range(6):
        for w in range(2):
            rec.add("local_step", worker=w, step=step, t0=t, dur=0.5,
                    synced=True, loss=1.0, drift=0.0)
        t += 0.5
    trace = _with_hlo(rec.freeze(), local_s=1e-3, sync_s=2e-3)
    trace.meta["measured"] = {"wall_s": t, "sync_count": 6,
                              "sync_steps": list(range(6))}
    r = replay(trace)
    assert r.priced_from == "warm_means"
    v = validate(trace)
    assert v["ok"] and v["ratio"] == pytest.approx(1.0)


def test_hlo_meta_malformed_falls_back_to_warm_means():
    for bad in ({}, {"local_step": {}},
                {"local_step": {"optimal_s": 0.0},
                 "sync_step": {"optimal_s": 1.0}},
                {"local_step": {"optimal_s": "x"},
                 "sync_step": {"optimal_s": 1.0}}):
        trace = _hand_trace()
        trace.meta["hlo_cost"] = bad
        r = replay(trace)
        assert r.priced_from == "warm_means"
        assert r.sync_overhead_s == pytest.approx(4.0)


def test_recorded_trace_carries_hlo_cost_and_health_args(fixed_h_run):
    # train --trace attaches the per-region cost tables and the health
    # numbers; the gate validates at the tighter HLO-priced tolerance
    _, trace = fixed_h_run
    hc = trace.meta.get("hlo_cost")
    assert hc, "train --trace should attach HLO region costs on CPU"
    for key in ("local_step", "sync_step"):
        tab = hc[key]
        assert tab["optimal_s"] > 0 and tab["n_regions"] >= 1
        # kept rows + dropped tail account for every region's optimal_s
        kept = sum(r["optimal_s"] for r in tab["regions"])
        assert kept + tab["dropped_optimal_s"] <= tab["optimal_s"] * (1 + 1e-9)
    steps = trace.by_name("local_step")
    assert all("grad_norm" in s.args and "b2" in s.args for s in steps)
    assert all(s.args["hlo_optimal_s"] ==
               pytest.approx(hc["local_step"]["optimal_s"]) for s in steps)
    enc = trace.by_name("ef_encode")
    assert enc and all("hlo_extra_optimal_s" in s.args for s in enc)
    v = validate(trace, tol=0.05)
    assert v["priced_from"] == "hlo_regions"
    assert v["ok"], v


def test_health_span_args_roundtrip_chrome(fixed_h_run):
    # the b2/grad_norm span args survive the Chrome export round-trip
    _, trace = fixed_h_run
    again = from_chrome(to_chrome(trace))
    a = [s for s in again.by_name("local_step")][0]
    b = [s for s in trace.by_name("local_step")][0]
    assert a.args["grad_norm"] == b.args["grad_norm"]
    assert a.args["b2"] == b.args["b2"]
