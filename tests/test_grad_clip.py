"""OptimizerConfig.grad_clip: global-norm clipping wired into the update paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core import optimizers as opt
from repro.launch.train import train_loop


def test_clip_by_global_norm_basics():
    g = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}   # norm = sqrt(180)
    clipped, factor = opt.clip_by_global_norm(g, 1.0)
    norm = float(opt.global_norm(clipped))
    assert norm == pytest.approx(1.0, rel=1e-6)
    assert float(factor) == pytest.approx(1.0 / np.sqrt(180.0), rel=1e-6)
    # below the threshold: bitwise untouched
    small = {"a": jnp.asarray([0.1, -0.2])}
    same, factor = opt.clip_by_global_norm(small, 10.0)
    np.testing.assert_array_equal(np.asarray(same["a"]),
                                  np.asarray(small["a"]))
    assert float(factor) == 1.0
    # 0 -> off, identical objects pass through
    off, factor = opt.clip_by_global_norm(g, 0.0)
    assert off is g


def test_clip_per_worker_rows():
    """batch_ndim=1 clips each worker's gradient independently."""
    g = {"w": jnp.stack([jnp.full(16, 10.0), jnp.full(16, 0.01)])}
    clipped, factor = opt.clip_by_global_norm(g, 1.0, batch_ndim=1)
    norms = np.sqrt(np.sum(np.square(np.asarray(clipped["w"])), axis=1))
    assert norms[0] == pytest.approx(1.0, rel=1e-5)      # clipped
    assert norms[1] == pytest.approx(0.04, rel=1e-5)     # untouched
    assert factor.shape == (2,)


def test_grad_clip_zero_is_identity():
    """grad_clip=0 must not change the optimizer at all (the old default)."""
    base = opt.local_adaalter(lr=0.5, H=4)
    assert opt.with_grad_clip(base, 0.0) is base
    cfg = OptimizerConfig(name="adaalter", grad_clip=0.0)
    o = opt.make_optimizer(cfg)
    params = {"w": jnp.ones(32)}
    g = {"w": jnp.full(32, 100.0)}
    sq = {"w": jnp.square(g["w"])}
    p_clip, _ = o.update(g, sq, o.init(params), params)
    o2 = opt.make_optimizer(OptimizerConfig(name="adaalter"))
    p_ref, _ = o2.update(g, sq, o2.init(params), params)
    np.testing.assert_array_equal(np.asarray(p_clip["w"]),
                                  np.asarray(p_ref["w"]))


def test_grad_clip_bounds_sync_update():
    """adaalter with grad_clip: the applied gradient has norm <= max_norm
    and B² accumulates the CLIPPED squares."""
    o = opt.make_optimizer(OptimizerConfig(
        name="adaalter", lr=1.0, eps=1.0, b0=1.0, warmup_steps=0,
        grad_clip=1.0))
    params = {"w": jnp.zeros(16)}
    state = o.init(params)
    g = {"w": jnp.full(16, 25.0)}                        # norm 100
    sq = {"w": jnp.square(g["w"])}
    new_params, new_state = o.update(g, sq, state, params)
    # update = -clipped / sqrt(b0² + eps²); ||clipped|| == 1
    assert float(opt.global_norm(new_params)) == pytest.approx(
        1.0 / np.sqrt(2.0), rel=1e-5)
    accumulated = np.asarray(new_state["b2"]["w"]) - 1.0   # minus b0²
    np.testing.assert_allclose(accumulated, 1.0 / 16.0, rtol=1e-5)


def test_grad_clip_local_step_matches_manual_clip():
    cfg = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=0,
                          grad_clip=0.5)
    o = opt.make_optimizer(cfg)
    base = opt.local_adaalter(lr=0.5, H=4, warmup_steps=0)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=64),
                               jnp.float32)}
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=64) * 10,
                          jnp.float32)}
    manual, _ = opt.clip_by_global_norm(g, 0.5)
    (p1, s1) = o.local_step(g, o.init(params), params)
    (p2, s2) = base.local_step(manual, base.init(params), params)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
    np.testing.assert_array_equal(np.asarray(s1["b2_local"]["w"]),
                                  np.asarray(s2["b2_local"]["w"]))


def test_grad_clip_composes_with_compression():
    """clip wraps the base BEFORE compressed_sync: residual state intact."""
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=1, warmup_steps=0,
        compression="int8", grad_clip=1.0))
    params = {"w": jnp.asarray(np.random.default_rng(2).normal(size=300),
                               jnp.float32)}
    state = o.init(params)
    assert "res_params" in state
    g = {"w": jnp.full(300, 5.0)}
    params, state = o.local_step(g, state, params)
    pre = np.asarray(params["w"]).copy()
    synced, state = o.sync(params, state)
    np.testing.assert_allclose(
        np.asarray(synced["w"]) + np.asarray(state["res_params"]["w"]),
        pre, rtol=0, atol=1e-6)


def test_grad_clip_train_loop_end_to_end():
    cfg = reduced(get_arch("biglstm"), vocab=128)
    shape = ShapeConfig(name="gc", seq_len=32, global_batch=8, kind="train")
    base = OptimizerConfig(name="local_adaalter", lr=0.5, H=2, warmup_steps=2)
    r_off = train_loop(cfg, shape, base, steps=6, verbose=False)
    # a tight clip must actually change the trajectory (not silently ignored)
    import dataclasses
    tight = dataclasses.replace(base, grad_clip=1e-3)
    r_on = train_loop(cfg, shape, tight, steps=6, verbose=False)
    assert np.isfinite(r_on.final_loss)
    assert r_on.losses != r_off.losses
