"""Serving path: prefill/decode consistency, cache geometry, per-family decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch, get_shape, reduced
from repro.launch.serving import (build_serve_programs, cache_geometry,
                                  decode_cache_specs, serve_batch_specs)
from repro.models import build_model

DECODE_FAMS = ["qwen2-7b", "mamba2-370m", "hymba-1.5b",
               "phi3.5-moe-42b-a6.6b", "llama-3.2-vision-11b",
               "seamless-m4t-large-v2", "biglstm"]


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", DECODE_FAMS)
def test_decode_step_per_family(arch):
    cfg = reduced(get_arch(arch))
    shape = ShapeConfig(name="decode_32k", seq_len=64, global_batch=2,
                        kind="decode")
    with _mesh() as mesh:
        sp = build_serve_programs(cfg, shape, mesh)
        params = sp.init_fn(jax.random.PRNGKey(0))
        cache = jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape, l.dtype),
                                       decode_cache_specs(cfg, shape))
        tok = jnp.ones((2, 1), jnp.int32)
        pos = jnp.asarray([3, 5], jnp.int32)
        logits, cache2 = sp.decode_step(params, cache, tok, pos)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_prefill_decode_consistency_dense():
    """Greedy decode over a teacher-forced prompt must reproduce the
    full-sequence logits position by position (same math, cached path)."""
    cfg = reduced(get_arch("phi4-mini-3.8b"), n_layers=2, d_model=128,
                  vocab=128)
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 128)
    full = model.logits_fn(params, {"tokens": tokens})          # (B,S,V)

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_prefill_decode_consistency_ssm():
    cfg = reduced(get_arch("mamba2-370m"), n_layers=2, vocab=128)
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 128)
    full = model.logits_fn(params, {"tokens": tokens})
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------- cache geometry (long_500k policy) ------------------------ #
def test_long500k_dense_uses_window():
    cfg = get_arch("qwen2-7b")
    cache_len, window, _ = cache_geometry(cfg, get_shape("long_500k"))
    assert window > 0 and cache_len == window            # bounded state
    assert cache_len < 524288


def test_long500k_ssm_has_no_kv_cache():
    cfg = get_arch("mamba2-370m")
    cache_len, window, _ = cache_geometry(cfg, get_shape("long_500k"))
    assert cache_len == 0
    specs = decode_cache_specs(cfg, get_shape("long_500k"))
    leaves = jax.tree_util.tree_leaves(specs)
    total = sum(np.prod(l.shape) for l in leaves)
    # O(1) state: far smaller than the 524k context
    assert total < 524288 * 64


def test_decode32k_full_cache():
    cfg = get_arch("phi4-mini-3.8b")
    cache_len, window, _ = cache_geometry(cfg, get_shape("decode_32k"))
    assert cache_len == 32768 and window == 0


def test_encdec_cross_cache_len():
    cfg = get_arch("seamless-m4t-large-v2")
    _, _, cross = cache_geometry(cfg, get_shape("decode_32k"))
    assert cross == 32768


def test_serve_batch_specs_modalities():
    vlm = get_arch("llama-3.2-vision-11b")
    specs = serve_batch_specs(vlm, get_shape("prefill_32k"))
    assert "image_embeds" in specs["prefill"]
    audio = get_arch("seamless-m4t-large-v2")
    specs = serve_batch_specs(audio, get_shape("prefill_32k"))
    assert "audio_frames" in specs["prefill"]
