"""Sharding rules and specs: resolution, shape-safety, worker-axis handling.

Includes the regression test for the worker-axis off-by-one (the spec used
to gain a leading None and silently lose its 'model' entry, replicating
every FFN weight across the TP axis — caught by the dry-run roofline).
"""
import subprocess
import sys

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ParallelismPlan
from repro.models import build_model
from repro.sharding.partition import ShardingRules
from repro.sharding.specs import param_shardings, shape_safe_spec

MESH = AbstractMesh((("data", 16), ("model", 16)))
POD_MESH = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _specs(cfg, plan, mesh, with_workers):
    rules = ShardingRules(mesh, plan)
    model = build_model(cfg)
    ab = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if with_workers:
        R = 1
        for a in plan.local_axes:
            R *= mesh.shape[a]
        ab = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((R,) + l.shape, l.dtype), ab)
    sh = param_shardings(rules, ab, with_workers=with_workers)
    flat = {}
    for path, s in jax.tree_util.tree_flatten_with_path(sh)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", "")))
                        for p in path)
        flat[name] = s.spec
    return flat


def test_worker_axis_specs_regression():
    """wq/w1/w2 must keep their 'model' axis when a worker axis is prepended."""
    cfg = get_arch("qwen2-7b")
    plan = ParallelismPlan(local_axes=("data",), grad_axes=(), fsdp_axes=())
    flat = _specs(cfg, plan, MESH, with_workers=True)
    # stacked blocks: leading (worker, layer) axes, then the weight body
    assert flat["blocks/0/mlp/w1"] == P("data", None, None, "model")
    assert flat["blocks/0/mlp/w2"] == P("data", None, "model", None)
    assert flat["blocks/0/attn/wq"] == P("data", None, None, "model")
    assert flat["blocks/0/attn/wo"] == P("data", None, "model", None)
    assert flat["embed"] == P("data", "model", None)
    assert flat["lm_head"] == P("data", None, "model")


def test_sync_plan_specs_no_worker_axis():
    cfg = get_arch("llama3-405b")
    plan = ParallelismPlan(local_axes=(), grad_axes=("data",),
                           fsdp_axes=("data",))
    flat = _specs(cfg, plan, MESH, with_workers=False)
    # FSDP: embed dim of weights sharded over data; TP over model
    assert flat["blocks/0/mlp/w1"] == P(None, "data", "model")
    assert flat["blocks/0/attn/wo"] == P(None, "model", "data")


def test_multi_pod_worker_tuple():
    cfg = get_arch("qwen2-7b")
    plan = ParallelismPlan(local_axes=("pod", "data"), grad_axes=(),
                           fsdp_axes=())
    flat = _specs(cfg, plan, POD_MESH, with_workers=True)
    assert flat["blocks/0/mlp/w1"] == P(("pod", "data"), None, None, "model")


def test_shape_safe_drops_non_dividing_axes():
    spec = shape_safe_spec((28, 128), P("model", None), MESH)   # 28 % 16 != 0
    assert spec == P(None, None)
    spec = shape_safe_spec((32, 128), P("model", None), MESH)
    assert spec == P("model", None)


def test_shape_safe_partial_tuple():
    # ('pod','data') over dim 4: pod(2) divides, data(16) doesn't -> keep pod
    spec = shape_safe_spec((4, 8), P(("pod", "data"), None), POD_MESH)
    assert spec == P("pod", None)


def test_moe_expert_axis():
    cfg = get_arch("phi3.5-moe-42b-a6.6b")
    plan = ParallelismPlan(local_axes=(), grad_axes=("data",),
                           fsdp_axes=("data",))
    flat = _specs(cfg, plan, MESH, with_workers=False)
    assert flat["blocks/0/moe/w1"] == P(None, "model", "data", None)


# --------------------------------------------------------------------------- #
# Numerical equivalence of the SHARDED local optimizer vs the single-device
# reference, on a real 4-device host mesh (subprocess: device count must be
# set before jax initializes).
# --------------------------------------------------------------------------- #
_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import ParallelismPlan
from repro.launch.steps import build_train_programs
from repro.data import SyntheticLM, make_train_batch

cfg = reduced(get_arch("minitron-4b"), n_layers=2, d_model=128, vocab=128)
cfg = dataclasses.replace(cfg, param_dtype="float32")
shape = ShapeConfig(name="t", seq_len=32, global_batch=8, kind="train")
opt_cfg = OptimizerConfig(name="local_adaalter", lr=0.3, H=2, warmup_steps=0)

def run(mesh_shape, axes, plan):
    mesh = jax.make_mesh(mesh_shape, axes)
    with mesh:
        pr = build_train_programs(cfg, shape, opt_cfg, mesh, plan)
        params, state = pr.init_fn(jax.random.PRNGKey(0))
        ds = SyntheticLM(vocab_size=128, seq_len=32, n_workers=2, seed=0)
        losses = []
        for step in range(4):
            b = make_train_batch(cfg, shape, ds, step, n_workers=2)
            b = jax.tree_util.tree_map(jnp.asarray, b)
            fn = pr.sync_step if (step+1) % 2 == 0 else pr.local_step
            params, state, m = fn(params, state, b)
            losses.append(float(m["loss"]))
        return losses, jax.tree_util.tree_map(lambda x: np.asarray(x, np.float32), params)

plan_sharded = ParallelismPlan(local_axes=("data",), grad_axes=(), fsdp_axes=())
l1, p1 = run((2, 2), ("data", "model"), plan_sharded)
l2, p2 = run((2, 1), ("data", "model"), plan_sharded)   # no TP
for a, b in zip(l1, l2):
    assert abs(a - b) < 2e-4, (l1, l2)
flat1 = jax.tree_util.tree_leaves(p1)
flat2 = jax.tree_util.tree_leaves(p2)
for a, b in zip(flat1, flat2):
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)
print("EQUIV-OK")
"""


@pytest.mark.slow
def test_sharded_equivalence_subprocess():
    r = subprocess.run([sys.executable, "-c", _EQUIV_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "EQUIV-OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
