"""Data pipeline: determinism, non-IID-ness, learnability floor."""
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch, reduced
from repro.data import SyntheticLM, make_train_batch


def test_deterministic_across_instances():
    a = SyntheticLM(vocab_size=128, seq_len=32, n_workers=4, seed=7)
    b = SyntheticLM(vocab_size=128, seq_len=32, n_workers=4, seed=7)
    ba = a.global_batch(3, 16)
    bb = b.global_batch(3, 16)
    for k in ba:
        np.testing.assert_array_equal(ba[k], bb[k])


def test_different_steps_differ():
    ds = SyntheticLM(vocab_size=128, seq_len=32, n_workers=1, seed=0)
    assert not np.array_equal(ds.worker_batch(0, 0, 8)["tokens"],
                              ds.worker_batch(0, 1, 8)["tokens"])


def test_labels_are_next_tokens():
    ds = SyntheticLM(vocab_size=128, seq_len=32, n_workers=1, seed=0)
    b = ds.worker_batch(0, 0, 8)
    # labels[t] is the process continuation of tokens; shifting tokens left
    # by one must equal labels except the final position.
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_non_iid_worker_distributions_differ():
    """The paper's assumption D_i != D_j: worker bigram stats must differ."""
    V = 64
    ds = SyntheticLM(vocab_size=V, seq_len=256, n_workers=2, seed=0,
                     noise=0.0, non_iid_frac=1.0)

    def bigram_table(w):
        counts = np.zeros((V, V))
        for s in range(4):
            b = ds.worker_batch(w, s, 16)
            seq = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
            for row in seq:
                counts[row[:-1], row[1:]] += 1
        return counts.argmax(axis=1)

    t0, t1 = bigram_table(0), bigram_table(1)
    assert (t0 != t1).mean() > 0.5          # mostly different transitions


def test_iid_mode_identical_tables():
    ds = SyntheticLM(vocab_size=64, seq_len=32, n_workers=3, seed=0,
                     non_iid=False)
    assert all((t == ds._shared).all() for t in ds._worker_tables)


def test_entropy_floor_finite_positive():
    ds = SyntheticLM(vocab_size=512, seq_len=32, n_workers=2, seed=0)
    h = ds.entropy_floor()
    assert 0.0 < h < np.log(512)


def test_modality_stubs_shapes():
    vlm = reduced(get_arch("llama-3.2-vision-11b"))
    audio = reduced(get_arch("seamless-m4t-large-v2"))
    shape = ShapeConfig(name="t", seq_len=16, global_batch=4, kind="train")
    ds = SyntheticLM(vocab_size=vlm.vocab_size, seq_len=16, n_workers=2)
    bv = make_train_batch(vlm, shape, ds, 0, n_workers=2)
    assert bv["image_embeds"].shape == (2, 2, vlm.n_image_tokens, vlm.d_model)
    ba = make_train_batch(audio, shape, ds, 0)
    assert ba["audio_frames"].shape == (4, 16, audio.d_model)
