"""Fused one-HBM-pass sync encode vs the three-pass composition, bitwise.

The acceptance bar for kernels/sync_fused.py: inside one compile unit (the
compiled sync_step is where all of this runs), the fused kernel must match
the error-feedback add + quantize + dequantize + residual-update chain
bit-for-bit — wire values, residuals, and the B² accumulator payloads that
become the denominators. Eager op-by-op execution is NOT the reference:
XLA contracts v − q·scale into an FMA when it compiles either path, so the
comparisons here jit both sides (exactly what the train step does).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig
from repro.core import optimizers as opt
from repro.core.codecs import get_codec
from repro.core.sync_engine import ef_apply
from repro.kernels.ref import fused_ef_blocks_ref
from repro.kernels.sync_fused import BLOCK, fused_ef_blocks, fused_ef_leaf

SHAPES = [
    (100,),                  # sub-block 1-D (padded path)
    (256,),                  # exactly one block
    (3000,),                 # non-multiple 1-D
    (4, 1000),               # batched leaf (worker axis)
    (2, 3, 130),             # 3-D leaf
    (600, 256),              # > one grid tile when tile_blocks is small
]


def _payload(shape, dtype, seed, scale=0.5):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return (x * scale).astype(dtype)


def _residual(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * 0.01


def _assert_bitwise(a, b, what=""):
    np.testing.assert_array_equal(
        np.asarray(a.astype(jnp.float32)), np.asarray(b.astype(jnp.float32)),
        err_msg=what)


# --------------------------------------------------------------------------- #
# kernel == oracle
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("clamp", [False, True])
def test_fused_kernel_matches_oracle(shape, dtype, clamp):
    bnd = 1 if len(shape) > 1 else 0
    x = _payload(shape, dtype, sum(shape))
    e = _residual(shape, 7)
    fk = jax.jit(functools.partial(fused_ef_leaf, batch_ndim=bnd,
                                   clamp_nonneg=clamp, use_pallas=True))
    fr = jax.jit(functools.partial(fused_ef_leaf, batch_ndim=bnd,
                                   clamp_nonneg=clamp, use_pallas=False))
    wk, rk = fk(x, e)
    wr, rr = fr(x, e)
    assert wk.dtype == x.dtype and rk.dtype == jnp.float32
    _assert_bitwise(wk, wr, "wire")
    _assert_bitwise(rk, rr, "residual")


def test_fused_blocks_zero_and_extreme_rows():
    x2d = jnp.concatenate([jnp.zeros((1, BLOCK)),           # all-zero block
                           jnp.full((1, BLOCK), -3.0),      # constant block
                           jnp.eye(1, BLOCK) * 1e4])        # one spike
    e2d = jnp.zeros_like(x2d)
    w, r = fused_ef_blocks(x2d, e2d, interpret=True)
    wr, rr = jax.jit(fused_ef_blocks_ref)(x2d, e2d)   # same-compile-unit rule
    np.testing.assert_array_equal(np.asarray(w), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))
    # zero block: scale 0 -> wire 0, residual 0 (error feedback has nothing
    # to re-send)
    assert np.all(np.asarray(w[0]) == 0) and np.all(np.asarray(r[0]) == 0)
    np.testing.assert_allclose(np.asarray(w[1]), -3.0, rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_error_feedback_identity(shape):
    """wire + residual == x + e exactly (what EF re-sends next round)."""
    bnd = 1 if len(shape) > 1 else 0
    x = _payload(shape, jnp.float32, 3)
    e = _residual(shape, 4)
    w, r = jax.jit(functools.partial(fused_ef_leaf, batch_ndim=bnd,
                                     use_pallas=True))(x, e)
    v = np.asarray(x, np.float64) + np.asarray(e, np.float64)
    np.testing.assert_allclose(np.asarray(w, np.float64)
                               + np.asarray(r, np.float64), v,
                               rtol=0, atol=np.abs(v).max() * 2e-7)


def test_clamp_nonneg_clamps_and_accounts_residual():
    x = jnp.linspace(-0.5, 1.0, 512)          # negative payload values
    e = jnp.zeros_like(x)
    w, r = jax.jit(functools.partial(fused_ef_leaf, clamp_nonneg=True,
                                     use_pallas=True))(x, e)
    assert float(jnp.min(w)) >= 0.0
    # clamped mass moves into the residual, not the void
    neg = np.asarray(x) < -1e-3
    np.testing.assert_allclose(np.asarray(r)[neg], np.asarray(x)[neg],
                               atol=1e-2)


# --------------------------------------------------------------------------- #
# fused == three-pass composition (ef_apply dispatch), one compile unit
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("clamp", [False, True])
def test_ef_apply_fused_matches_three_pass(use_pallas, clamp):
    tree = {"a": _payload((3, 1000), jnp.float32, 0),
            "b": _payload((2, 3, 130), jnp.bfloat16, 1)}
    res = {"a": _residual((3, 1000), 2), "b": _residual((2, 3, 130), 3)}
    fused = get_codec("int8", use_pallas=use_pallas, fused=True)
    unfused = get_codec("int8", use_pallas=use_pallas, fused=False)
    assert fused.ef_roundtrip is not None and unfused.ef_roundtrip is None
    jf = jax.jit(lambda t, r: ef_apply(t, r, fused, 1, clamp_nonneg=clamp))
    ju = jax.jit(lambda t, r: ef_apply(t, r, unfused, 1, clamp_nonneg=clamp))
    (wf, rf), (wu, ru) = jf(tree, res), ju(tree, res)
    for k in tree:
        _assert_bitwise(wf[k], wu[k], f"wire[{k}]")
        _assert_bitwise(rf[k], ru[k], f"residual[{k}]")


def test_ef_apply_lossless_codec_zero_residual():
    tree = {"w": _payload((300,), jnp.float32, 5)}
    res = {"w": _residual((300,), 6)}
    w, r = ef_apply(tree, res, get_codec("fp32"), 0)
    np.testing.assert_allclose(
        np.asarray(w["w"]), np.asarray(tree["w"]) + np.asarray(res["w"]),
        rtol=1e-7)
    assert np.abs(np.asarray(r["w"])).max() == 0.0


# --------------------------------------------------------------------------- #
# end-to-end: compressed_sync(fused) == compressed_sync(unfused), bitwise
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("use_pallas", [False, True])
def test_compressed_sync_fused_bitwise_end_to_end(use_pallas):
    """Three H=2 windows of Local AdaAlter through jitted step+sync: params,
    error-feedback residuals AND the synced B² denominators must agree
    bit-for-bit between the fused and three-pass engines."""

    def build(fused):
        o = opt.make_optimizer(OptimizerConfig(
            name="local_adaalter", lr=0.3, H=2, warmup_steps=0,
            compression="int8", use_pallas=use_pallas, sync_fused=fused))

        @jax.jit
        def window(params, state, gs):
            for g in gs:
                params, state = o.local_step({"w": g}, state, params)
            return o.sync(params, state)

        return o, window

    rng = np.random.default_rng(0)
    gs0 = [jnp.asarray(rng.normal(size=700) * 0.1, jnp.float32)
           for _ in range(6)]
    outs = {}
    for fused in (True, False):
        o, window = build(fused)
        params = {"w": jnp.asarray(
            np.random.default_rng(1).normal(size=700), jnp.float32)}
        state = o.init(params)
        for t in range(3):
            params, state = window(params, state, gs0[2 * t:2 * t + 2])
        outs[fused] = (params, state)
    (p1, s1), (p2, s2) = outs[True], outs[False]
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
    for key in ("b2_sync", "b2_local", "res_params", "res_b2"):
        np.testing.assert_array_equal(
            np.asarray(s1[key]["w"]), np.asarray(s2[key]["w"]),
            err_msg=key)


# --------------------------------------------------------------------------- #
# property tests (hypothesis; skipped where it is not installed)
# --------------------------------------------------------------------------- #
try:
    import hypothesis  # noqa: F401
    _HAS_HYP = True
except ImportError:
    _HAS_HYP = False

if _HAS_HYP:
    from hypothesis import given, settings, strategies as st
    import hypothesis.extra.numpy as hnp

    finite = st.floats(min_value=-100, max_value=100, allow_nan=False,
                       allow_infinity=False, width=32)

    @settings(max_examples=20, deadline=None)
    @given(hnp.arrays(np.float32, st.integers(1, 700).map(lambda n: (n,)),
                      elements=finite),
           st.integers(0, 2 ** 31 - 1), st.booleans())
    def test_property_fused_matches_oracle(xs, seed, clamp):
        x = jnp.asarray(xs)
        e = jax.random.normal(jax.random.PRNGKey(seed), x.shape,
                              jnp.float32) * 0.01
        fk = jax.jit(functools.partial(fused_ef_leaf, clamp_nonneg=clamp,
                                       use_pallas=True))
        fr = jax.jit(functools.partial(fused_ef_leaf, clamp_nonneg=clamp,
                                       use_pallas=False))
        (wk, rk), (wr, rr) = fk(x, e), fr(x, e)
        np.testing.assert_array_equal(np.asarray(wk), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
        # EF identity: what is sent plus what is kept is what was owed
        v = np.asarray(x, np.float64) + np.asarray(e, np.float64)
        if not clamp:
            np.testing.assert_allclose(
                np.asarray(wk, np.float64) + np.asarray(rk, np.float64), v,
                rtol=0, atol=max(np.abs(v).max(), 1.0) * 2e-7)

    @settings(max_examples=20, deadline=None)
    @given(hnp.arrays(np.float32, st.tuples(st.integers(1, 5),
                                            st.integers(1, 520)),
                      elements=finite))
    def test_property_blocks_never_straddle_workers(x2w):
        """Per-worker payload boundary: quantizing the stacked (R, n) leaf
        with batch_ndim=1 equals quantizing each worker's row alone."""
        x = jnp.asarray(x2w)
        e = jnp.zeros_like(x)
        w, r = fused_ef_leaf(x, e, batch_ndim=1, use_pallas=False)
        for i in range(x.shape[0]):
            wi, ri = fused_ef_leaf(x[i], e[i], batch_ndim=0,
                                   use_pallas=False)
            np.testing.assert_array_equal(np.asarray(w[i]), np.asarray(wi))
            np.testing.assert_array_equal(np.asarray(r[i]), np.asarray(ri))
