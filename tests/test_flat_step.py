"""Flat-plane step/sync vs the per-leaf path: bitwise, end to end.

The acceptance bar for the flat parameter plane (core/flatspace.py +
launch/steps._flat_programs): with the SAME config, the flat train step and
the per-leaf train step must produce bit-identical state — params, both B²
accumulators, and the error-feedback residuals (which pin the sync wire:
residual = v − wire) — on local steps AND sync rounds, for every codec and
for both the Pallas kernels and the jnp fallbacks. Checkpoints must restore
across the two layouts in both directions without breaking the bits.
"""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.data import SyntheticLM, make_train_batch
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs
from repro.launch.train import make_cpu_mesh, train_loop

CFG = reduced(get_arch("biglstm"), vocab=128)
SHAPE = ShapeConfig(name="t", seq_len=16, global_batch=4, kind="train")


def _opt(flat, compression="", use_pallas=False, fused=True, H=2,
         **kwargs):
    return OptimizerConfig.from_sync(
        SyncConfig(compression=compression, fused=fused, **kwargs),
        name="local_adaalter", lr=0.5, H=H, warmup_steps=3,
        use_pallas=use_pallas, flat=flat)


def _assert_tree_bitwise(a, b, what=""):
    fa, _ = jax.tree_util.tree_flatten(a)
    fb, _ = jax.tree_util.tree_flatten(b)
    for i, (x, y) in enumerate(zip(fa, fb)):
        np.testing.assert_array_equal(
            np.asarray(x.astype(jnp.float32)),
            np.asarray(y.astype(jnp.float32)), err_msg=f"{what}[{i}]")


# --------------------------------------------------------------------------- #
# the core pin: flat == per-leaf, state bitwise, local + sync steps
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("compression,use_pallas", [
    ("", False),            # uncompressed, jnp fallback update
    ("int8", False),        # fused EF encode, jnp fallback
    ("int8", True),         # Pallas: ONE update launch + ONE EF launch
    ("bf16", False),        # elementwise wire truncation
])
def test_flat_step_bitwise_matches_per_leaf(compression, use_pallas):
    mesh = make_cpu_mesh()
    with mesh:
        plan = resolve_plan(CFG, mesh, optimizer="local_adaalter")
        pL = build_train_programs(CFG, SHAPE, _opt(False, compression,
                                                   use_pallas), mesh, plan)
        pF = build_train_programs(CFG, SHAPE, _opt(True, compression,
                                                   use_pallas), mesh, plan)
        fs = pF.flatspace
        R = pL.n_workers
        ds = SyntheticLM(vocab_size=CFG.vocab_size, seq_len=SHAPE.seq_len,
                         n_workers=R, seed=0, non_iid=True)
        paramsL, stateL = pL.init_fn(jax.random.PRNGKey(0))
        planeF, stateF = pF.init_fn(jax.random.PRNGKey(0))
        for step in range(3):                      # local, sync, post-sync
            batch = jax.tree_util.tree_map(
                jnp.asarray,
                make_train_batch(CFG, SHAPE, ds, step, n_workers=R))
            sync = (step + 1) % 2 == 0
            paramsL, stateL, _ = (pL.sync_step if sync
                                  else pL.local_step)(paramsL, stateL, batch)
            planeF, stateF, _ = (pF.sync_step if sync
                                 else pF.local_step)(planeF, stateF, batch)
            _assert_tree_bitwise(paramsL, fs.unpack(planeF),
                                 f"params@{step}")
            for key in ("b2_sync", "b2_local", "res_params", "res_b2"):
                if key in stateL:
                    _assert_tree_bitwise(
                        stateL[key],
                        fs.unpack(stateF[key], dtype=jnp.float32),
                        f"{key}@{step}")
            np.testing.assert_array_equal(np.asarray(stateL["step"]),
                                          np.asarray(stateF["step"]))
            np.testing.assert_array_equal(np.asarray(stateL["tprime"]),
                                          np.asarray(stateF["tprime"]))


def test_flat_requires_local_adaalter():
    mesh = make_cpu_mesh()
    with mesh:
        plan = resolve_plan(CFG, mesh, optimizer="local_sgd")
        with pytest.raises(ValueError, match="flat"):
            build_train_programs(
                CFG, SHAPE,
                OptimizerConfig(name="local_sgd", flat=True), mesh, plan)


def test_flat_requires_positive_eps():
    mesh = make_cpu_mesh()
    with mesh:
        plan = resolve_plan(CFG, mesh, optimizer="local_adaalter")
        with pytest.raises(ValueError, match="eps"):
            build_train_programs(
                CFG, SHAPE,
                OptimizerConfig(name="local_adaalter", eps=0.0, flat=True),
                mesh, plan)


# --------------------------------------------------------------------------- #
# checkpoints cross the layout boundary in both directions, bitwise
# --------------------------------------------------------------------------- #
def test_checkpoint_cross_layout_bitwise(tmp_path):
    """per-leaf ckpt -> flat continuation -> flat ckpt -> per-leaf
    continuation: every hand-off lands mid-H-window and the final states
    agree bit-for-bit with the never-converted per-leaf run."""
    d_leaf, d_flat = str(tmp_path / "leaf"), str(tmp_path / "flat")
    kw = dict(steps=2, checkpoint_dir=d_leaf, checkpoint_every=2,
              verbose=False, non_iid=True)
    opt_leaf = _opt(False, "int8", H=4)
    opt_flat = _opt(True, "int8", H=4)
    # prefix: per-leaf to step 2 (mid-window: H=4 syncs at 3, 7, ...)
    train_loop(CFG, SHAPE, opt_leaf, **kw)
    shutil.copytree(d_leaf, d_flat)
    # continue per-leaf vs flat (restores the LEGACY ckpt into flat mode)
    a = train_loop(CFG, SHAPE, opt_leaf, **{**kw, "steps": 6,
                                            "checkpoint_dir": d_leaf})
    b = train_loop(CFG, SHAPE, opt_flat, **{**kw, "steps": 6,
                                            "checkpoint_dir": d_flat})
    assert a.start_step == b.start_step == 2
    assert a.sync_steps == b.sync_steps
    # the step-6 checkpoints (one per-leaf, one packed planes) hold the
    # same bits
    mesh = make_cpu_mesh()
    from repro.checkpoint import restore_checkpoint
    from repro.core.sync_engine import SyncState
    with mesh:
        plan = resolve_plan(CFG, mesh, optimizer="local_adaalter")
        pF = build_train_programs(CFG, SHAPE, opt_flat, mesh, plan)
    (sl, step_l) = restore_checkpoint(
        d_leaf, (*pF.legacy_abstract, SyncState.make()))
    (sf, step_f) = restore_checkpoint(
        d_flat, (*pF.flat_abstract, SyncState.make()))
    assert step_l == step_f == 6
    params_f, opt_f = pF.to_legacy(sf[0], sf[1])
    _assert_tree_bitwise(sl[0], params_f, "params@6")
    for key in ("b2_sync", "b2_local", "res_params", "res_b2"):
        _assert_tree_bitwise(sl[1][key], opt_f[key], f"{key}@6")
    np.testing.assert_array_equal(np.asarray(sl[2].since),
                                  np.asarray(sf[2].since))
    # and back: restore the FLAT ckpt into per-leaf mode, continue both
    c = train_loop(CFG, SHAPE, opt_leaf, **{**kw, "steps": 8,
                                            "checkpoint_dir": d_leaf})
    d = train_loop(CFG, SHAPE, opt_leaf, **{**kw, "steps": 8,
                                            "checkpoint_dir": d_flat})
    assert c.start_step == d.start_step == 6
    assert c.sync_steps == d.sync_steps
    (sl8, _) = restore_checkpoint(
        d_leaf, (*pF.legacy_abstract, SyncState.make()))
    (sf8, _) = restore_checkpoint(
        d_flat, (*pF.legacy_abstract, SyncState.make()))
    _assert_tree_bitwise(sl8[0], sf8[0], "params@8")
    for key in ("b2_sync", "b2_local", "res_params", "res_b2"):
        _assert_tree_bitwise(sl8[1][key], sf8[1][key], f"{key}@8")


def test_adaptive_midwindow_restore_into_flat(tmp_path):
    """Mid-window ADAPTIVE restore from a legacy per-leaf checkpoint into
    --flat mode: the engine's SyncState (window position + drift
    accumulator) survives the layout conversion and the run resumes the
    adaptive schedule instead of re-anchoring at the restore point."""
    ckpt = str(tmp_path / "ck")
    sync_kw = dict(policy="adaptive", threshold=0.05, h_min=2, h_max=8,
                   drift_metric="update_norm")
    opt_leaf = _opt(False, "int8", H=4, **sync_kw)
    opt_flat = _opt(True, "int8", H=4, **sync_kw)
    full = train_loop(CFG, SHAPE, opt_leaf, steps=8, verbose=False)
    train_loop(CFG, SHAPE, opt_leaf, steps=3, checkpoint_dir=ckpt,
               checkpoint_every=3, verbose=False)
    res = train_loop(CFG, SHAPE, opt_flat, steps=8, checkpoint_dir=ckpt,
                     checkpoint_every=0, verbose=False)
    assert res.start_step == 3 and res.steps == 5
    assert res.sync_policy == "adaptive"
    assert np.isfinite(res.final_loss)
    # the restored run continues a schedule, not restarts one: its syncs
    # all land after the restore point and stay within h_max of each other
    assert all(s >= 3 for s in res.sync_steps)
    assert abs(res.final_loss - full.final_loss) / abs(full.final_loss) < 0.1
