"""Shard-aligned FlatSpace: geometry, eps guard, cross-mesh adapters, and
the 4-device bitwise pins for the sharded flat plane.

The sharded path's core invariant: on a (workers x shards) mesh the flat
plane trains *bitwise* equal to the replicated flat plane (and hence, via
the tier-1 flat pins, to the per-leaf path).  The tail-pad-only layout is
what makes the cross-mesh adapters trivial: slot offsets never move with
the shard count, only the zero tail grows or shrinks.

Multi-device cases run in subprocesses because the XLA host-device count
must be fixed before the backend initialises (same pattern as
tests/test_sharding.py).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import OptimizerConfig
from repro.configs.base import SyncConfig
from repro.core.flatspace import ALIGN, FlatSpace, adapt_flat_state

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, marker: str, timeout: int = 900) -> None:
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "JAX_PLATFORMS": "cpu"}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert marker in proc.stdout, proc.stdout + "\n" + proc.stderr


# --------------------------------------------------------------------- #
# shard geometry (1 device, in-process)                                 #
# --------------------------------------------------------------------- #

def _tree():
    import jax.numpy as jnp
    return {"a": jnp.zeros((2, 300, 257)), "b": jnp.zeros((2, 77)),
            "c": jnp.zeros((2, 1))}


def test_shard_geometry_offsets_stable():
    """Slot offsets must not move with the shard count (tail-pad-only);
    the plane must tile into shard-count equal, ALIGN-multiple pieces."""
    base = FlatSpace.build(_tree(), batch_ndim=1)
    for shards in (1, 2, 4):
        fs = FlatSpace.build(_tree(), batch_ndim=1, shards=shards)
        assert fs.plane_size % (shards * ALIGN) == 0
        assert fs.shard_size * shards == fs.plane_size
        for s0, s1 in zip(base.slots, fs.slots):
            assert (s0.offset, s0.padded) == (s1.offset, s1.padded)
        assert fs.plane_size >= base.plane_size


def test_shard_pack_unpack_roundtrip():
    import jax.numpy as jnp
    tree = _tree()
    fs = FlatSpace.build(tree, batch_ndim=1, shards=4)
    plane = fs.pack(tree)
    assert plane.shape == (2, fs.plane_size)
    out = fs.unpack(plane)
    for k in tree:
        assert (np.asarray(out[k]) == np.asarray(tree[k])).all()
    # the shard tail beyond the last slot is all zero padding
    end = fs.slots[-1].offset + fs.slots[-1].padded
    assert not np.asarray(plane[:, end:]).any()


# --------------------------------------------------------------------- #
# eps guard (satellite: --flat with eps == 0 corrupts the padding)      #
# --------------------------------------------------------------------- #

def test_flat_config_rejects_nonpositive_eps():
    with pytest.raises(ValueError, match="eps"):
        OptimizerConfig.from_sync(SyncConfig(), name="local_adaalter",
                                  lr=0.1, eps=0.0, flat=True)
    # per-leaf mode tolerates eps == 0 (no padding to protect)
    OptimizerConfig.from_sync(SyncConfig(), name="local_adaalter",
                              lr=0.1, eps=0.0, flat=False)


def test_flatspace_rejects_nonpositive_eps():
    with pytest.raises(ValueError, match="eps"):
        FlatSpace.build(_tree(), batch_ndim=1, eps=0.0)
    FlatSpace.build(_tree(), batch_ndim=1, eps=1e-7)   # fine
    FlatSpace.build(_tree(), batch_ndim=1, eps=None)   # per-leaf adapters


# --------------------------------------------------------------------- #
# cross-mesh host adapters                                              #
# --------------------------------------------------------------------- #

def _state(workers, plane_size, seed=0):
    rng = np.random.default_rng(seed)
    plane = rng.standard_normal((workers, plane_size)).astype(np.float32)
    state = {"b2_sync": rng.random((workers, plane_size)).astype(np.float32),
             "step": np.full((workers,), 7, np.int32),
             "tprime": np.zeros((workers,), np.float32)}
    return plane, state


def test_adapt_grow_shrink_roundtrip_bit_exact():
    p0, s0 = _state(1, 11 * ALIGN)
    p1, s1 = adapt_flat_state(p0, s0, workers=2, plane_size=12 * ALIGN)
    assert p1.shape == (2, 12 * ALIGN)
    assert (p1[0] == p1[1]).all()                    # replicated rows
    assert not p1[:, 11 * ALIGN:].any()              # zero tail pad
    p2, s2 = adapt_flat_state(p1, s1, workers=1, plane_size=11 * ALIGN)
    assert (p2 == p0).all()
    for k in s0:
        assert (s2[k] == s0[k]).all(), k


def test_adapt_shrink_merges_diverged_workers():
    p0, s0 = _state(4, 2 * ALIGN)
    p1, s1 = adapt_flat_state(p0, s0, workers=2, plane_size=2 * ALIGN)
    want = p0.reshape(2, 2, -1).mean(axis=1).astype(np.float32)
    assert (p1 == want).all()
    assert s1["step"].shape == (2,) and (s1["step"] == 7).all()


def test_adapt_refuses_lossy_truncation():
    p0, s0 = _state(1, 2 * ALIGN)
    with pytest.raises(ValueError, match="truncate"):
        adapt_flat_state(p0, s0, workers=1, plane_size=ALIGN)


def test_adapt_refuses_nondivisible_workers():
    p0, s0 = _state(3, ALIGN)
    with pytest.raises(ValueError):
        adapt_flat_state(p0, s0, workers=2, plane_size=ALIGN)


# --------------------------------------------------------------------- #
# 4-device pins (subprocess: sharded == replicated, cross-mesh ckpt)    #
# --------------------------------------------------------------------- #

_BITWISE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.data import SyntheticLM, make_train_batch
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs

CFG = reduced(get_arch("biglstm"), vocab=128)
SHAPE = ShapeConfig(name="t", seq_len=16, global_batch=4, kind="train")
mesh = jax.make_mesh((2, 2), ("data", "model"))

def run(opt_cfg, plan):
    with mesh:
        pr = build_train_programs(CFG, SHAPE, opt_cfg, mesh, plan)
        R = pr.n_workers
        ds = SyntheticLM(vocab_size=128, seq_len=16, n_workers=R, seed=0,
                         non_iid=True)
        plane, state = pr.init_fn(jax.random.PRNGKey(0))
        for step in range(3):
            b = jax.tree_util.tree_map(jnp.asarray,
                make_train_batch(CFG, SHAPE, ds, step, n_workers=R))
            fn = pr.sync_step if (step + 1) % 2 == 0 else pr.local_step
            plane, state, _ = fn(plane, state, b)
        return pr, np.asarray(plane), {k: np.asarray(v)
                                       for k, v in state.items()}

def trim(a, b):
    n = min(a.shape[-1], b.shape[-1])
    big = a if a.shape[-1] > n else b
    assert not np.asarray(big[..., n:]).any(), "nonzero shard tail"
    return a[..., :n], b[..., :n]

for comp, pallas in [("", False), ("int8", True), ("int8", False),
                     ("bf16", False)]:
    opt = OptimizerConfig.from_sync(
        SyncConfig(compression=comp, fused=True),
        name="local_adaalter", lr=0.5, H=2, warmup_steps=3,
        use_pallas=pallas, flat=True)
    plan = resolve_plan(CFG, mesh, optimizer="local_adaalter")
    prS, plS, stS = run(opt, plan)
    prR, plR, stR = run(opt, dataclasses.replace(plan, tp_axis=""))
    assert prS.n_shards == 2 and prR.n_shards == 1, (prS.n_shards,
                                                     prR.n_shards)
    a, b = trim(plS, plR)
    assert (a == b).all(), (comp, pallas, float(np.abs(a - b).max()))
    for k in sorted(set(stS) | set(stR)):
        x, y = stS[k], stR[k]
        if x.ndim and x.shape[-1] != y.shape[-1] and x.shape[-1] > 4:
            x, y = trim(x, y)
        assert x.shape == y.shape and (x == y).all(), (comp, pallas, k)
    print("ok", comp or "fp32", "pallas" if pallas else "jnp")
print("SHARDED-BITWISE-OK")
"""

_CKPT = r"""
import tempfile
import numpy as np
import jax
from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.launch.train import train_loop

CFG = reduced(get_arch("biglstm"), vocab=128)
SHAPE = ShapeConfig(name="t", seq_len=16, global_batch=4, kind="train")
OPT = OptimizerConfig.from_sync(
    SyncConfig(compression="int8", fused=True, policy="adaptive",
               threshold=0.02, h_min=2, h_max=8),
    name="local_adaalter", lr=0.5, H=4, warmup_steps=2,
    use_pallas=True, flat=True)
small = jax.make_mesh((1, 1), ("data", "model"))
big = jax.make_mesh((2, 2), ("data", "model"))
with tempfile.TemporaryDirectory() as d:
    r1 = train_loop(CFG, SHAPE, OPT, steps=3, mesh=small, checkpoint_dir=d,
                    checkpoint_every=3, verbose=False)
    # restore mid-H-window (H=4, ckpt at 3) onto the sharded mesh
    r2 = train_loop(CFG, SHAPE, OPT, steps=6, mesh=big, checkpoint_dir=d,
                    checkpoint_every=3, verbose=False)
    assert r2.start_step == 3, r2.start_step
    assert all(np.isfinite(r2.losses)), r2.losses
    # and back: the (2,2) checkpoint at step 6 restores on (1,1)
    r3 = train_loop(CFG, SHAPE, OPT, steps=8, mesh=small, checkpoint_dir=d,
                    verbose=False)
    assert r3.start_step == 6, r3.start_step
    assert all(np.isfinite(r3.losses)), r3.losses
print("CROSS-MESH-CKPT-OK")
"""


@pytest.mark.slow
def test_sharded_flat_bitwise_matches_replicated():
    """(2 workers x 2-way FSDP) flat plane == replicated flat plane,
    bitwise, across {fp32, int8 pallas, int8 jnp, bf16} after 3 steps
    including a mid-window sync."""
    _run(_BITWISE, "SHARDED-BITWISE-OK")


@pytest.mark.slow
def test_flat_checkpoint_restores_across_meshes():
    """Flat checkpoints round-trip (1,1) -> (2,2) -> (1,1), resuming the
    adaptive schedule mid-H-window with finite losses."""
    _run(_CKPT, "CROSS-MESH-CKPT-OK")
