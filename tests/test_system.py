"""End-to-end system behaviour: training converges, H-trade-off, restart."""
import math

import jax
import numpy as np
import pytest

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.launch.train import train_loop

SHAPE = ShapeConfig(name="sys", seq_len=64, global_batch=8, kind="train")


def _cfg(vocab=256):
    return reduced(get_arch("biglstm"), vocab=vocab)


def test_training_reduces_loss():
    # the transformer family learns the bigram stream fastest on CPU budgets
    cfg = reduced(get_arch("qwen2-7b"), n_layers=2, d_model=128, vocab=256)
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=10)
    res = train_loop(cfg, SHAPE, opt, steps=60, verbose=False)
    start = float(np.mean(res.losses[:5]))
    assert res.final_loss < start - 0.3, (start, res.final_loss)
    # never worse than uniform prediction
    assert res.final_loss < math.log(cfg.vocab_size) + 0.5


def test_adaalter_tracks_adagrad():
    """Paper Table 2: AdaAlter's convergence is ~AdaGrad's."""
    cfg = _cfg()
    r_ada = train_loop(cfg, SHAPE, OptimizerConfig(
        name="adagrad", lr=0.5, warmup_steps=0), steps=50, verbose=False)
    r_alt = train_loop(cfg, SHAPE, OptimizerConfig(
        name="adaalter", lr=0.5, warmup_steps=0), steps=50, verbose=False)
    assert abs(r_ada.final_loss - r_alt.final_loss) < 0.15


def test_larger_H_not_better():
    """Theorem 2: noise grows with H — final loss for H=8 shouldn't beat
    H=1 by any meaningful margin on the same stream."""
    cfg = _cfg()
    losses = {}
    for H in (1, 8):
        r = train_loop(cfg, SHAPE, OptimizerConfig(
            name="local_adaalter", lr=0.5, H=H, warmup_steps=10),
            steps=60, verbose=False)
        losses[H] = r.final_loss
    assert losses[8] > losses[1] - 0.05, losses


def test_checkpoint_restart_continues(tmp_path):
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=2, warmup_steps=5)
    d = str(tmp_path / "ckpt")
    # run 20 steps, checkpointing every 10
    r1 = train_loop(cfg, SHAPE, opt, steps=20, checkpoint_dir=d,
                    checkpoint_every=10, verbose=False)
    # "crash" and resume: asks for 30 steps, restores at 20, runs 10 more
    r2 = train_loop(cfg, SHAPE, opt, steps=30, checkpoint_dir=d,
                    checkpoint_every=10, verbose=False)
    assert len(r2.losses) == 10
    # a fresh 30-step run on the same stream must agree with the resumed one
    r3 = train_loop(cfg, SHAPE, opt, steps=30, verbose=False)
    np.testing.assert_allclose(r2.losses, r3.losses[20:], rtol=1e-4, atol=1e-4)


def test_non_iid_harder_than_iid():
    """Sanity: the non-IID stream (paper assumption) is at least as hard."""
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=10)
    r_iid = train_loop(cfg, SHAPE, opt, steps=50, non_iid=False, verbose=False)
    r_non = train_loop(cfg, SHAPE, opt, steps=50, non_iid=True, verbose=False)
    assert r_non.final_loss > r_iid.final_loss - 0.2
