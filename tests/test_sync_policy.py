"""Sync-policy subsystem: fixed_h bit-identity, adaptive bounds, measured comm."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.comm import sync_payload_bytes
from repro.core.sync_policy import (AdaptiveSyncPolicy, FixedHPolicy,
                                    make_sync_policy)
from repro.data import SyntheticLM, make_train_batch
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs
from repro.launch.train import make_cpu_mesh, train_loop

SHAPE = ShapeConfig(name="pol", seq_len=32, global_batch=8, kind="train")


def _cfg(vocab=128):
    return reduced(get_arch("biglstm"), vocab=vocab)


def _drive(policy, n_steps, drift=0.0, start=0):
    """Run a policy host-side with a constant per-step drift statistic."""
    policy.reset(start)
    synced = []
    for step in range(start, start + n_steps):
        s = policy.want_sync(step)
        policy.observe(step, s, {"drift": drift})
        if s:
            synced.append(step)
    return synced


# --------------------------------------------------------------------------- #
# policy unit behaviour (pure host-side, no jax)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("H", [1, 3, 4])
def test_fixed_h_matches_modulo(H):
    pol = FixedHPolicy(H)
    want = [s for s in range(20) if (s + 1) % H == 0]
    assert _drive(pol, 20) == want
    assert pol.sync_count == len(want)


def test_fixed_h_restore_keeps_global_anchor():
    """Restoring mid-window must continue the PRE-restore schedule."""
    pol = FixedHPolicy(4)
    assert _drive(pol, 10, start=6) == [7, 11, 15]   # (step+1) % 4 == 0


def test_adaptive_threshold_zero_syncs_every_h_min():
    pol = AdaptiveSyncPolicy(threshold=0.0, h_min=3, h_max=12)
    assert _drive(pol, 12) == [2, 5, 8, 11]


def test_adaptive_threshold_inf_syncs_every_h_max():
    pol = AdaptiveSyncPolicy(threshold=math.inf, h_min=1, h_max=5)
    assert _drive(pol, 15, drift=1e9) == [4, 9, 14]


def test_adaptive_h_min_equals_h_max_is_fixed_h():
    pol = AdaptiveSyncPolicy(threshold=0.123, h_min=4, h_max=4)
    assert _drive(pol, 16, drift=0.5) == _drive(FixedHPolicy(4), 16)


def test_adaptive_triggers_on_accumulated_drift():
    # drift 0.2/step, threshold 0.5, h_min 2: the 4th step since a sync is
    # the first with accumulated drift >= 0.5 (the deciding step's own drift
    # is not yet known — the policy runs before the step)
    pol = AdaptiveSyncPolicy(threshold=0.5, h_min=2, h_max=10)
    assert _drive(pol, 12, drift=0.2) == [3, 7, 11]


def test_adaptive_reset_clears_window():
    pol = AdaptiveSyncPolicy(threshold=1e9, h_min=1, h_max=4)
    _drive(pol, 3)                 # mid-window
    assert _drive(pol, 8, start=3) == [6, 10]   # window re-anchored at 3


def test_policy_validation():
    with pytest.raises(ValueError, match="h_max"):
        AdaptiveSyncPolicy(threshold=0.1, h_min=4, h_max=2)
    with pytest.raises(ValueError, match="h_min"):
        AdaptiveSyncPolicy(threshold=0.1, h_min=0)
    with pytest.raises(ValueError, match="sync_policy"):
        make_sync_policy(OptimizerConfig(sync_policy="sometimes"))
    with pytest.raises(ValueError, match="local optimizer"):
        make_sync_policy(OptimizerConfig(name="adaalter",
                                         sync_policy="adaptive"),
                         is_local=False)


def test_make_sync_policy_defaults():
    pol = make_sync_policy(OptimizerConfig(H=4))
    assert isinstance(pol, FixedHPolicy) and pol.H == 4
    pol = make_sync_policy(OptimizerConfig(H=4, sync_policy="adaptive",
                                           sync_threshold=0.1))
    assert isinstance(pol, AdaptiveSyncPolicy)
    assert pol.h_max == 16                        # h_max=0 -> 4*H


# --------------------------------------------------------------------------- #
# train_loop integration: bit-identity and measured comm
# --------------------------------------------------------------------------- #
def _manual_modulo_loop(cfg, shape, opt_cfg, steps, seed=0):
    """The historical train loop: sync iff (step+1) % H == 0."""
    mesh = make_cpu_mesh()
    plan = resolve_plan(cfg, mesh, optimizer=opt_cfg.name)
    with mesh:
        programs = build_train_programs(cfg, shape, opt_cfg, mesh, plan)
        R = programs.n_workers if programs.is_local else 1
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                         n_workers=max(R, 1), seed=seed, non_iid=True)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(seed))
        H = programs.H if programs.is_local else 1
        losses, sync_steps = [], []
        for step in range(steps):
            batch = jax.tree_util.tree_map(jnp.asarray, make_train_batch(
                cfg, shape, ds, step,
                n_workers=R if programs.is_local else 0))
            do_sync = ((step + 1) % H == 0)
            fn = programs.sync_step if do_sync else programs.local_step
            params, opt_state, metrics = fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if do_sync:
                sync_steps.append(step)
    return losses, sync_steps


def test_fixed_h_bit_identical_to_modulo_loop():
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=5)
    res = train_loop(cfg, SHAPE, opt, steps=10, verbose=False)
    want_losses, want_syncs = _manual_modulo_loop(cfg, SHAPE, opt, steps=10)
    assert res.losses == want_losses           # bitwise, not allclose
    assert res.sync_steps == want_syncs == [3, 7]


def test_fixed_h_bit_identical_with_restore(tmp_path):
    """Restore into the middle of an H-window: same schedule, same losses."""
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=5)
    d = str(tmp_path / "ckpt")
    train_loop(cfg, SHAPE, opt, steps=6, checkpoint_dir=d,
               checkpoint_every=6, verbose=False)       # stop mid-window
    r2 = train_loop(cfg, SHAPE, opt, steps=13, checkpoint_dir=d,
                    checkpoint_every=100, verbose=False)
    assert r2.start_step == 6
    # schedule stays anchored at global step 0, not the restore point
    assert r2.sync_steps == [7, 11]
    want_losses, _ = _manual_modulo_loop(cfg, SHAPE, opt, steps=13)
    np.testing.assert_allclose(r2.losses, want_losses[6:], rtol=1e-5,
                               atol=1e-5)
    # measured comm comes from the policy's sync count over executed steps —
    # NOT the static 2P/H formula, which this restore violates (2 syncs in
    # the 7 post-restore steps)
    per_round = sync_payload_bytes("local_adaalter", _n_params(cfg))
    assert r2.sync_count == 2
    np.testing.assert_allclose(r2.comm_bytes_per_step, 2 * per_round / 7)
    assert not np.isclose(r2.comm_bytes_per_step, r2.comm_bytes_modeled)


def _n_params(cfg):
    from repro.models.counting import count_params
    return count_params(cfg)


def test_measured_comm_matches_modeled_on_full_windows():
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=5)
    res = train_loop(cfg, SHAPE, opt, steps=8, verbose=False)
    assert res.sync_count == 2
    np.testing.assert_allclose(res.comm_bytes_per_step,
                               res.comm_bytes_modeled)
    assert res.comm_bytes_total == res.sync_count * sync_payload_bytes(
        "local_adaalter", _n_params(cfg))


def test_adaptive_end_to_end_respects_bounds():
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, warmup_steps=5,
                          sync_policy="adaptive", sync_threshold=0.02,
                          h_min=2, h_max=6)
    res = train_loop(cfg, SHAPE, opt, steps=18, verbose=False)
    assert res.sync_policy == "adaptive"
    assert 3 <= res.sync_count <= 9            # 18/h_max .. 18/h_min
    gaps = np.diff([-1] + res.sync_steps)
    assert gaps.min() >= 2 and gaps.max() <= 6
    # measured accounting follows the triggered schedule
    per_round = sync_payload_bytes("local_adaalter", _n_params(cfg))
    np.testing.assert_allclose(res.comm_bytes_total,
                               res.sync_count * per_round)
    assert np.isfinite(res.final_loss)


def _step_metrics(opt):
    cfg = _cfg()
    mesh = make_cpu_mesh()
    plan = resolve_plan(cfg, mesh, optimizer=opt.name)
    with mesh:
        programs = build_train_programs(cfg, SHAPE, opt, mesh, plan)
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=SHAPE.seq_len,
                         n_workers=programs.n_workers, seed=0, non_iid=True)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(0))
        batch = jax.tree_util.tree_map(jnp.asarray, make_train_batch(
            cfg, SHAPE, ds, 0, n_workers=programs.n_workers))
        _, _, metrics = programs.local_step(params, opt_state, batch)
    return metrics


def test_steps_emit_drift_metric_for_adaptive_only():
    """The compiled local step reports the divergence statistic iff the
    adaptive policy (its only consumer) is configured."""
    adaptive = OptimizerConfig(name="local_adaalter", lr=0.5, warmup_steps=0,
                               sync_policy="adaptive", sync_threshold=0.01)
    drift = float(_step_metrics(adaptive)["drift"])
    assert np.isfinite(drift) and drift > 0.0
    fixed = OptimizerConfig(name="local_adaalter", lr=0.5, H=4,
                            warmup_steps=0)
    assert "drift" not in _step_metrics(fixed)
