"""HLO cost-walk parser edge cases (roofline/hlo_cost.py).

Hand-written optimized-HLO snippets pin the rules the region attribution
and the replay pricing depend on: fusion byte accounting (sliced big
operands, in-place dynamic-update-slice roots), while-loop trip counts
(backend_config vs condition-constant fallback), dot/convolution flop
rules, and the sums-to-entry-cost invariant of ``region_costs``.
"""
import pytest

from repro.roofline.hlo_cost import HloCostModel, hlo_cost, region_table


def _module(body: str) -> str:
    return "HloModule test\n\n" + body


# --------------------------------------------------------------------------- #
# flop rules
# --------------------------------------------------------------------------- #
DOT = _module("""
ENTRY %main (x: f32[8,32], y: f32[32,16]) -> f32[8,16] {
  %x = f32[8,32]{1,0} parameter(0)
  %y = f32[32,16]{1,0} parameter(1)
  ROOT %d = f32[8,16]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
""")


def test_dot_flops_use_contracting_dims():
    c = hlo_cost(DOT)
    # 2 * out_elems(128) * contracted(32)
    assert c.flops == pytest.approx(2.0 * 8 * 16 * 32)
    # bytes: out 512 + operands 1024 + 2048
    assert c.bytes == pytest.approx(8 * 16 * 4 + 8 * 32 * 4 + 32 * 16 * 4)


CONV = _module("""
ENTRY %main (in: f32[1,10,10,4], k: f32[3,3,4,16]) -> f32[1,8,8,16] {
  %in = f32[1,10,10,4]{3,2,1,0} parameter(0)
  %k = f32[3,3,4,16]{3,2,1,0} parameter(1)
  ROOT %cv = f32[1,8,8,16]{3,2,1,0} convolution(%in, %k), window={size=3x3}, dim_labels=b01f_01io->b01f
}
""")


def test_conv_flops_use_window_and_cin():
    c = hlo_cost(CONV)
    # 2 * out_elems(1024) * window(3x3) * cin(4)
    assert c.flops == pytest.approx(2.0 * 1024 * 9 * 4)


# --------------------------------------------------------------------------- #
# while trip counts
# --------------------------------------------------------------------------- #
WHILE_BODY = """
%cond (cp: (s32[], f32[128])) -> pred[] {
  %cp = (s32[], f32[128]{0}) parameter(0)
  %cg = s32[] get-tuple-element(%cp), index=0
  %climit = s32[] constant(7)
  ROOT %lt = pred[] compare(%cg, %climit), direction=LT
}

%body (bp: (s32[], f32[128])) -> (s32[], f32[128]) {
  %bp = (s32[], f32[128]{0}) parameter(0)
  %bg0 = s32[] get-tuple-element(%bp), index=0
  %bone = s32[] constant(1)
  %bnext = s32[] add(%bg0, %bone)
  %bg1 = f32[128]{0} get-tuple-element(%bp), index=1
  %bmul = f32[128]{0} multiply(%bg1, %bg1)
  ROOT %bt = (s32[], f32[128]{0}) tuple(%bnext, %bmul)
}
"""

WHILE_KNOWN = _module(WHILE_BODY + """
ENTRY %main (init: (s32[], f32[128])) -> (s32[], f32[128]) {
  %init = (s32[], f32[128]{0}) parameter(0)
  ROOT %w = (s32[], f32[128]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
""")

WHILE_FALLBACK = _module(WHILE_BODY + """
ENTRY %main (init: (s32[], f32[128])) -> (s32[], f32[128]) {
  %init = (s32[], f32[128]{0}) parameter(0)
  ROOT %w = (s32[], f32[128]{0}) while(%init), condition=%cond, body=%body
}
""")

# one body iteration: add(1 flop) + multiply(128 flops)
_BODY_FLOPS = 129.0


def test_while_uses_known_trip_count():
    c = hlo_cost(WHILE_KNOWN)
    assert c.flops == pytest.approx(5 * _BODY_FLOPS)


def test_while_falls_back_to_condition_constant():
    # no backend_config: the largest integer constant in the condition (7)
    # bounds the loop; the body's own constant(1) must NOT win
    c = hlo_cost(WHILE_FALLBACK)
    assert c.flops == pytest.approx(7 * _BODY_FLOPS)


def test_while_region_records_trip():
    model = HloCostModel(WHILE_KNOWN)
    regions = model.region_costs()
    whiles = [r for r in regions if r.opcode == "while"]
    assert len(whiles) == 1 and whiles[0].trip == 5


# --------------------------------------------------------------------------- #
# fusion byte accounting
# --------------------------------------------------------------------------- #
FUSION_SLICE = _module("""
%fused_slice (p0: f32[1048576], p1: s32[]) -> f32[32] {
  %p0 = f32[1048576]{0} parameter(0)
  %p1 = s32[] parameter(1)
  %ds = f32[32]{0} dynamic-slice(%p0, %p1), dynamic_slice_sizes={32}
  ROOT %neg = f32[32]{0} negate(%ds)
}

ENTRY %main (big: f32[1048576], i: s32[]) -> f32[32] {
  %big = f32[1048576]{0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = f32[32]{0} fusion(%big, %i), kind=kLoop, calls=%fused_slice
}
""")


def test_fusion_charges_slice_not_full_operand():
    c = hlo_cost(FUSION_SLICE)
    # the 4 MB buffer is only dynamic-sliced inside the fusion: traffic is
    # the 128 B slice + the scalar index + the 128 B output, NOT 4 MB
    assert c.bytes == pytest.approx(32 * 4 + 4 + 32 * 4)
    assert c.flops == pytest.approx(32)           # the negate


FUSION_DUS = _module("""
%fused_dus (p0: f32[1048576], p1: f32[256], p2: s32[]) -> f32[1048576] {
  %p0 = f32[1048576]{0} parameter(0)
  %p1 = f32[256]{0} parameter(1)
  %p2 = s32[] parameter(2)
  ROOT %dus = f32[1048576]{0} dynamic-update-slice(%p0, %p1, %p2)
}

ENTRY %main (buf: f32[1048576], upd: f32[256], i: s32[]) -> f32[1048576] {
  %buf = f32[1048576]{0} parameter(0)
  %upd = f32[256]{0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[1048576]{0} fusion(%buf, %upd, %i), kind=kLoop, calls=%fused_dus
}
""")


def test_fusion_dus_root_writes_update_slice_only():
    c = hlo_cost(FUSION_DUS)
    # in-place DUS: read the target slice (via the min() on the operand,
    # 1024 B), read the update (1024 B) + index (4 B), write 2x1024 B out
    assert c.bytes == pytest.approx(1024 + 1024 + 4 + 2 * 1024)


FUSION_MIXED = _module("""
%fused_mixed (p0: f32[1048576]) -> f32[1048576] {
  %p0 = f32[1048576]{0} parameter(0)
  ROOT %ng = f32[1048576]{0} negate(%p0)
}

ENTRY %main (big: f32[1048576]) -> f32[1048576] {
  %big = f32[1048576]{0} parameter(0)
  ROOT %f = f32[1048576]{0} fusion(%big), kind=kLoop, calls=%fused_mixed
}
""")


def test_fusion_elementwise_consumer_charges_full_operand():
    # the big operand is consumed elementwise (negate), not sliced: the
    # slice-only discount must NOT apply
    c = hlo_cost(FUSION_MIXED)
    assert c.bytes == pytest.approx(2 * 1048576 * 4)


# --------------------------------------------------------------------------- #
# region attribution
# --------------------------------------------------------------------------- #
COMPOSITE = _module(WHILE_BODY + """
%fused_add (fa: f32[128], fb: f32[128]) -> f32[128] {
  %fa = f32[128]{0} parameter(0)
  %fb = f32[128]{0} parameter(1)
  ROOT %fadd = f32[128]{0} add(%fa, %fb)
}

ENTRY %main (init: (s32[], f32[128]), v: f32[128]) -> f32[128] {
  %init = (s32[], f32[128]{0}) parameter(0)
  %v = f32[128]{0} parameter(1)
  %w = (s32[], f32[128]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %wv = f32[128]{0} get-tuple-element(%w), index=1
  %f = f32[128]{0} fusion(%wv, %v), kind=kLoop, calls=%fused_add
  %ar = f32[128]{0} all-reduce(%f), replica_groups={}, to_apply=%fused_add
  %e1 = f32[128]{0} exponential(%ar)
  ROOT %e2 = f32[128]{0} tanh(%e1)
}
""")


def test_regions_sum_to_entry_cost():
    model = HloCostModel(COMPOSITE)
    total = model.entry_cost()
    regions = model.region_costs()
    assert sum(r.flops for r in regions) == pytest.approx(total.flops)
    assert sum(r.bytes for r in regions) == pytest.approx(total.bytes)
    assert (sum(r.coll_bytes for r in regions)
            == pytest.approx(sum(total.coll.values())))


def test_region_kinds_and_unfused_merge():
    regions = HloCostModel(COMPOSITE).region_costs()
    kinds = [r.opcode for r in regions]
    assert kinds.count("while") == 1
    assert kinds.count("fusion") == 1
    assert kinds.count("all-reduce") == 1
    # the loose exponential + tanh merge into ONE trailing region
    unfused = [r for r in regions if r.opcode == "(unfused)"]
    assert len(unfused) == 1
    assert unfused[0].flops == pytest.approx(2 * 128)
    coll = [r for r in regions if r.opcode == "all-reduce"][0]
    assert coll.coll_bytes == pytest.approx(128 * 4)


def test_region_table_truncation_is_visible():
    tab = region_table(COMPOSITE, peak_flops=1e12, hbm_bw=1e11, top=1)
    assert tab["n_regions"] == 4
    assert len(tab["regions"]) == 1
    # the dropped tail is summarized, and kept + dropped covers every region
    full = region_table(COMPOSITE, peak_flops=1e12, hbm_bw=1e11, top=0)
    all_opt = sum(r["optimal_s"] for r in full["regions"])
    kept = tab["regions"][0]["optimal_s"]
    assert kept + tab["dropped_optimal_s"] == pytest.approx(all_opt)
    # totals stay FULL-program regardless of truncation
    assert tab["flops"] == full["flops"] and tab["bytes"] == full["bytes"]
    # rows are sorted most-expensive-first
    opts = [r["optimal_s"] for r in full["regions"]]
    assert opts == sorted(opts, reverse=True)


def test_region_table_on_real_compiled_program():
    # end-to-end: a jitted program's optimized HLO parses and the totals
    # match the entry-cost walk
    import jax
    import jax.numpy as jnp

    def f(x, y):
        return jnp.tanh(x @ y).sum()

    x = jnp.ones((32, 64), jnp.float32)
    y = jnp.ones((64, 16), jnp.float32)
    txt = jax.jit(f).lower(x, y).compile().as_text()
    tab = region_table(txt, peak_flops=1e12, hbm_bw=1e11)
    total = hlo_cost(txt)
    assert tab["flops"] == pytest.approx(total.flops)
    assert tab["bytes"] == pytest.approx(total.bytes)
    assert tab["n_regions"] >= 1
    assert tab["flops"] >= 2.0 * 32 * 16 * 64    # at least the matmul
