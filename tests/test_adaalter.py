"""Faithfulness of the JAX optimizers to the paper's Algorithms 1-4."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt
from repro.core import reference as ref

def _run_sync(optimizer, x0, grads):
    """Drive an Optimizer with per-worker grads (T,n,d)."""
    params = {"w": jnp.asarray(x0)}
    state = optimizer.init(params)
    out = []
    for g in grads:
        gm = {"w": jnp.asarray(g.mean(axis=0))}
        sq = {"w": jnp.asarray((g ** 2).mean(axis=0))}
        params, state = optimizer.update(gm, sq, state, params)
        out.append(np.asarray(params["w"]))
    return np.asarray(out), state


def _run_local(optimizer, x0, grads, n):
    """Drive a LocalOptimizer with a stacked worker axis (vmap'd local steps,
    mean-over-axis-0 sync) — the same representation the production
    train_step uses."""
    H = optimizer.H
    params = {"w": jnp.broadcast_to(jnp.asarray(x0), (n,) + x0.shape)}
    state = jax.vmap(optimizer.init)(params)
    vstep = jax.vmap(optimizer.local_step)

    def mean_fn(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                       x.shape), tree)

    out = []
    for t, g in enumerate(grads, start=1):
        params, state = vstep({"w": jnp.asarray(g)}, state, params)
        if t % H == 0:
            params, state = optimizer.sync(params, state, mean_fn)
        out.append(np.asarray(params["w"]))
    return np.asarray(out), state


@pytest.fixture
def problem():
    rng = np.random.default_rng(0)
    T, n, d = 24, 4, 16
    grads = rng.normal(size=(T, n, d))
    x0 = rng.normal(size=d)
    return x0, grads


def test_adagrad_matches_paper(problem):
    x0, grads = problem
    ours, state = _run_sync(opt.adagrad(lr=0.5, eps=1.0, b0=0.0), x0, grads)
    want, b2 = ref.ref_adagrad(x0, grads, lr=0.5, eps=1.0, b0=0.0)
    np.testing.assert_allclose(ours, want, rtol=3e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state["b2"]["w"]), b2, rtol=3e-5)


def test_adaalter_matches_paper(problem):
    x0, grads = problem
    ours, state = _run_sync(opt.adaalter(lr=0.5, eps=1.0, b0=1.0), x0, grads)
    want, b2 = ref.ref_adaalter(x0, grads, lr=0.5, eps=1.0, b0=1.0)
    np.testing.assert_allclose(ours, want, rtol=3e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state["b2"]["w"]), b2, rtol=3e-5)


def test_adaalter_updates_before_accumulating(problem):
    """The defining AdaAlter property: step 1 uses only b0²+ε², not G²."""
    x0, grads = problem
    o = opt.adaalter(lr=1.0, eps=1.0, b0=1.0)
    params = {"w": jnp.asarray(x0)}
    state = o.init(params)
    g = {"w": jnp.asarray(grads[0].mean(axis=0))}
    sq = {"w": jnp.asarray((grads[0] ** 2).mean(axis=0))}
    new_params, _ = o.update(g, sq, state, params)
    expected = x0 - grads[0].mean(axis=0) / np.sqrt(1.0 + 1.0)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expected, rtol=3e-6)


@pytest.mark.parametrize("H", [2, 4, 8])
def test_local_adaalter_matches_paper(problem, H):
    x0, grads = problem
    n = grads.shape[1]
    ours, _ = _run_local(opt.local_adaalter(lr=0.5, eps=1.0, b0=1.0, H=H),
                         x0, grads, n)
    want, _ = ref.ref_local_adaalter(x0, grads, lr=0.5, eps=1.0, H=H, b0=1.0)
    np.testing.assert_allclose(ours, want, rtol=3e-5, atol=1e-6)


@pytest.mark.parametrize("H", [2, 4])
def test_local_sgd_matches_paper(problem, H):
    x0, grads = problem
    n = grads.shape[1]
    ours, _ = _run_local(opt.local_sgd(lr=0.3, H=H), x0, grads, n)
    want = ref.ref_local_sgd(x0, grads, lr=0.3, H=H)
    np.testing.assert_allclose(ours, want, rtol=3e-5, atol=1e-6)


def test_local_adaalter_h1_equals_adaalter(problem):
    """H=1 must reduce Local AdaAlter to fully-synchronous AdaAlter exactly."""
    x0, grads = problem
    n = grads.shape[1]
    local, _ = _run_local(opt.local_adaalter(lr=0.5, eps=1.0, b0=1.0, H=1),
                          x0, grads, n)
    sync_, _ = _run_sync(opt.adaalter(lr=0.5, eps=1.0, b0=1.0), x0, grads)
    for i in range(n):
        np.testing.assert_allclose(local[:, i], sync_, rtol=1e-6, atol=1e-7)


def test_denominator_identical_across_workers(problem):
    """Paper §4.3: denominators are the same on different workers between syncs."""
    x0, grads = problem
    n = grads.shape[1]
    o = opt.local_adaalter(lr=0.5, eps=1.0, b0=1.0, H=4)
    params = {"w": jnp.broadcast_to(jnp.asarray(x0), (n,) + x0.shape)}
    state = jax.vmap(o.init)(params)
    vstep = jax.vmap(o.local_step)
    for g in grads[:3]:                            # 3 local steps, no sync
        params, state = vstep({"w": jnp.asarray(g)}, state, params)
        denom = (np.asarray(state["b2_sync"]["w"])
                 + np.asarray(state["tprime"])[:, None].astype(float))
        for i in range(1, n):
            np.testing.assert_array_equal(denom[0], denom[i])
        # params DO diverge between syncs (that's the point of local SGD)
        assert not np.allclose(np.asarray(params["w"][0]),
                               np.asarray(params["w"][1]))


def test_warmup_schedule():
    """Paper §6.2.1: eta_t = eta * min(1, t/warmup)."""
    lr = 0.5
    for t, want in [(1, 0.5 / 600), (300, 0.25), (600, 0.5), (10000, 0.5)]:
        got = float(opt.warmup_lr(lr, jnp.asarray(t), 600))
        np.testing.assert_allclose(got, want, rtol=1e-6)
