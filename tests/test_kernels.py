"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.adaalter_update import LANES, fused_update
from repro.kernels.ops import tree_fused_update
from repro.kernels.ref import fused_update_ref

SHAPES = [
    (128,),                  # tiny 1-D (padded path)
    (1000,),                 # non-multiple 1-D
    (512, 128),              # exactly one tile
    (4096, 128),             # multi-block
    (48, 257),               # ragged 2-D
    (3, 5, 64),              # 3-D leaf
    (2048, 512),             # big leaf
]


def _mk(shape, dtype, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = (jax.random.normal(ks[1], shape, jnp.float32) * 0.1).astype(dtype)
    bs = jax.random.uniform(ks[2], shape, jnp.float32, 1.0, 5.0)
    bl = bs + jax.random.uniform(ks[3], shape, jnp.float32, 0.0, 2.0)
    return x, g, bs, bl


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_matches_ref(shape, dtype):
    x, g, bs, bl = _mk(shape, dtype, hash((shape, str(dtype))) % 2**31)
    eta, extra = 0.37, 3.0
    y, nbl = fused_update(x, g, bs, bl, eta, extra, interpret=True,
                          block_rows=256)
    y_ref, nbl_ref = fused_update_ref(x, g, bs, bl, eta, extra)
    assert y.dtype == x.dtype and nbl.dtype == jnp.float32
    # rsqrt*mul (kernel) vs div/sqrt (oracle) may differ by 1 ulp of the dtype
    rtol = 1e-6 if dtype == jnp.float32 else 8e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=rtol, atol=1e-6)
    np.testing.assert_allclose(np.asarray(nbl), np.asarray(nbl_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_rows", [8, 64, 512])
def test_block_shape_sweep(block_rows):
    shape = (block_rows * 3 * LANES + 17,)       # force padding
    x, g, bs, bl = _mk(shape, jnp.float32, block_rows)
    y, nbl = fused_update(x, g, bs, bl, 0.5, 2.0, interpret=True,
                          block_rows=block_rows)
    y_ref, nbl_ref = fused_update_ref(x, g, bs, bl, 0.5, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(nbl), np.asarray(nbl_ref),
                               rtol=1e-6, atol=1e-7)


def test_tree_update_matches_local_adaalter_step():
    """The fused kernel must reproduce LocalOptimizer.local_step exactly."""
    from repro.core import optimizers as opt

    o = opt.local_adaalter(lr=0.5, eps=1.0, b0=1.0, H=4)
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (300,)),
              "b": {"w": jax.random.normal(key, (64, 65))}}
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape) * 0.1, params)
    state = o.init(params)

    want_p, want_s = o.local_step(grads, state, params)

    tprime = 1
    eta = float(opt.warmup_lr(0.5, jnp.asarray(1), 0))
    got_p, got_bl = tree_fused_update(params, grads, state["b2_sync"],
                                      state["b2_local"], eta,
                                      tprime * 1.0, use_pallas=True)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-6, atol=1e-7),
        got_p, want_p)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-6, atol=1e-7),
        got_bl, want_s["b2_local"])


# --------------------------------------------------------------------------- #
# SSD chunk-scan kernel (kernels/ssd_scan.py)
# --------------------------------------------------------------------------- #
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ref import ssd_ref

SSD_SHAPES = [
    # (b, nz, c, nh, hd, n)
    (1, 2, 8, 2, 16, 8),
    (2, 4, 16, 4, 32, 16),
    (2, 3, 32, 2, 64, 32),
    (1, 8, 64, 2, 64, 128),      # production-like chunk/state dims
]


@pytest.mark.parametrize("dims", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(dims, dtype):
    b, nz, c, nh, hd, n = dims
    ks = jax.random.split(jax.random.PRNGKey(sum(dims)), 4)
    xbar = (jax.random.normal(ks[0], (b, nz, c, nh, hd)) * 0.2).astype(dtype)
    Bm = (jax.random.normal(ks[1], (b, nz, c, n)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[2], (b, nz, c, n)) * 0.3).astype(dtype)
    dA = -jnp.abs(jax.random.normal(ks[3], (b, nz, c, nh))) * 0.1
    y_k = ssd_scan(xbar, Bm, Cm, dA, interpret=True)
    y_r = ssd_ref(xbar, Bm, Cm, dA)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=tol, atol=tol)


def test_ssm_pallas_flag_model_level():
    """logits with the fused kernel == pure-jnp SSD path (mamba2 family)."""
    import dataclasses
    from repro.configs import get_arch, reduced
    from repro.models import build_model
    cfg0 = dataclasses.replace(reduced(get_arch("mamba2-370m"), vocab=128),
                               param_dtype="float32")
    cfg1 = dataclasses.replace(cfg0, ssm_pallas=True)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    l0 = m0.logits_fn(params, {"tokens": tok})
    l1 = m1.logits_fn(params, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-4, atol=2e-4)
