"""Wire codecs: bf16/fp32 numerics, accounting, error feedback (mirrors the
int8 coverage in tests/test_quantize.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig
from repro.core import optimizers as opt
from repro.core.codecs import CODEC_NAMES, get_codec
from repro.core.comm import (payload_bytes, sync_bytes_per_step,
                             sync_payload_bytes)

BF16_REL = 2.0 ** -8           # half-ulp relative error of a bf16 truncation


# --------------------------------------------------------------------------- #
# codec protocol
# --------------------------------------------------------------------------- #
def test_codec_registry():
    assert get_codec("").name == "fp32"
    for name in CODEC_NAMES:
        c = get_codec(name)
        assert c.name == name
        assert c.lossless == (name == "fp32")
    c = get_codec("int8")
    assert get_codec(c) is c                      # WireCodec passes through
    with pytest.raises(ValueError, match="compression"):
        get_codec("fp4")


def test_fp32_codec_is_identity():
    c = get_codec("fp32")
    x = jax.random.normal(jax.random.PRNGKey(0), (300,))
    np.testing.assert_array_equal(np.asarray(c.roundtrip(x)), np.asarray(x))
    assert c.wire_bytes(256, 4) == 1024.0


@pytest.mark.parametrize("shape", [(100,), (4, 1000), (2, 3, 130)])
def test_bf16_roundtrip_error_bounded(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    c = get_codec("bf16")
    y = c.roundtrip(x, batch_ndim=1 if len(shape) > 1 else 0)
    assert y.dtype == jnp.float32
    rel = np.abs(np.asarray(y) - np.asarray(x)) / np.abs(np.asarray(x))
    assert rel.max() <= BF16_REL * 1.01
    # encode actually puts bf16 on the wire
    assert c.encode(x, 0).dtype == jnp.bfloat16


def test_codec_wire_bytes():
    assert get_codec("bf16").wire_bytes(256, 4) == 512.0
    assert get_codec("int8").wire_bytes(256, 4) == 260.0
    # comm accounting dispatches through the codec
    assert payload_bytes(256, compression="bf16") == 512.0
    assert payload_bytes(256, compression="fp32") == 1024.0


def test_sync_bytes_bf16_halves_payload():
    P, H = 10_000_000, 4
    full = sync_bytes_per_step("local_adaalter", P, H)
    half = sync_bytes_per_step("local_adaalter", P, H, compression="bf16")
    assert full / half == pytest.approx(2.0)
    assert sync_payload_bytes("local_adaalter", P) == pytest.approx(8.0 * P)
    assert sync_payload_bytes("local_sgd", P, compression="bf16") \
        == pytest.approx(2.0 * P)


# --------------------------------------------------------------------------- #
# compressed_sync over the bf16 codec (mirrors the int8 tests)
# --------------------------------------------------------------------------- #
def test_fp32_codec_returns_base():
    base = opt.local_adaalter(H=4)
    assert opt.compressed_sync(base, "fp32") is base
    o = opt.make_optimizer(OptimizerConfig(name="local_adaalter",
                                           compression="fp32"))
    assert "res_params" not in o.init({"w": jnp.zeros(4)})


def test_bf16_residual_is_exact_truncation_error():
    """After a sync, wire + residual must reconstruct params + old residual."""
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=1, warmup_steps=0,
        compression="bf16"))
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=500),
                               jnp.float32)}
    state = o.init(params)
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=500) * 0.1,
                          jnp.float32)}
    params, state = o.local_step(g, state, params)
    pre_sync = np.asarray(params["w"]).copy()
    synced, state = o.sync(params, state)       # identity mean_fn (1 worker)
    np.testing.assert_allclose(
        np.asarray(synced["w"]) + np.asarray(state["res_params"]["w"]),
        pre_sync, rtol=0, atol=1e-6)
    # residuals bounded by half a bf16 ulp of the payload
    res = np.abs(np.asarray(state["res_params"]["w"]))
    assert res.max() <= np.abs(pre_sync).max() * BF16_REL * 1.01


def test_bf16_local_step_matches_base():
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=4, warmup_steps=0,
        compression="bf16"))
    base = opt.local_adaalter(lr=0.3, H=4, warmup_steps=0)
    params = {"w": jnp.ones(300)}
    s, sb = o.init(params), base.init(params)
    g = {"w": jnp.full(300, 0.1)}
    (p1, s1), (p2, s2) = o.local_step(g, s, params), base.local_step(g, sb, params)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
    np.testing.assert_array_equal(np.asarray(s1["b2_local"]["w"]),
                                  np.asarray(s2["b2_local"]["w"]))


def test_bf16_b2_sync_stays_nonnegative():
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=1, warmup_steps=0,
        compression="bf16", b0=0.01))
    params = {"w": jnp.linspace(-1.0, 1.0, 512)}
    state = o.init(params)
    for t in range(3):
        g = {"w": jnp.sin(jnp.arange(512.0) + t) * 0.01}
        params, state = o.local_step(g, state, params)
        params, state = o.sync(params, state)
    assert float(jnp.min(state["b2_sync"]["w"])) >= 0.0


def test_bf16_convergence_tracks_uncompressed():
    """Toy non-IID quadratic, 2 workers: bf16+EF within 10% of fp32 sync."""
    n, d, H, T = 2, 512, 4, 64
    target = np.random.default_rng(0).normal(size=d).astype(np.float32)

    def mean_fn(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                       x.shape), tree)

    def run(compression):
        o = opt.make_optimizer(OptimizerConfig(
            name="local_adaalter", lr=0.3, H=H, warmup_steps=0,
            compression=compression))
        params = {"w": jnp.zeros((n, d), jnp.float32)}
        state = jax.vmap(o.init)(params)
        vstep = jax.vmap(o.local_step)
        rng = np.random.default_rng(1)
        for t in range(1, T + 1):
            g = (np.asarray(params["w"]) - target[None]
                 + rng.normal(size=(n, d)) * 0.1)
            params, state = vstep({"w": jnp.asarray(g, jnp.float32)},
                                  state, params)
            if t % H == 0:
                params, state = o.sync(params, state, mean_fn)
        return float(np.mean((np.asarray(params["w"]) - target[None]) ** 2))

    l_fp32, l_bf16 = run(""), run("bf16")
    assert l_bf16 < l_fp32 * 1.1 + 1e-4, (l_fp32, l_bf16)
