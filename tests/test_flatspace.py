"""FlatSpace geometry: pack/unpack round-trips, buckets, sidecars, adapters.

The flat parameter plane (core/flatspace.py) may only ever be a LAYOUT
change: packing any architecture's parameter tree into the plane and
unpacking it back must reproduce every leaf bit-for-bit, dtype included —
across dtype buckets (bf16 params next to fp32 norms), worker-stacked
leaves, and the optimizer-state adapters the checkpoint round-trips use.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.flatspace import (FlatSpace, flat_abstract,
                                  is_flat_checkpoint, mean_planes,
                                  pack_opt_state, unpack_opt_state)
from repro.models import build_model

#: one member of each structural family the ISSUE calls out (LSTM,
#: dense transformer, SSM, MoE) plus the hybrid for good measure.
ARCHS = ["biglstm", "qwen2-7b", "mamba2-370m", "phi3.5-moe-42b-a6.6b"]


def _params(arch):
    cfg = reduced(get_arch(arch), vocab=128)
    return build_model(cfg).init(jax.random.PRNGKey(0))


def _assert_tree_bitwise(a, b):
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(fa, fb):
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(
            np.asarray(x.astype(jnp.float32)),
            np.asarray(y.astype(jnp.float32)))


# --------------------------------------------------------------------------- #
# pack/unpack round-trip, every architecture family
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCHS)
def test_pack_unpack_bitwise_roundtrip(arch):
    params = _params(arch)
    fs = FlatSpace.build(params, batch_ndim=0)
    plane = fs.pack(params)
    assert plane.dtype == jnp.float32
    assert plane.shape == (fs.plane_size,)
    assert fs.plane_size % fs.align == 0
    _assert_tree_bitwise(params, fs.unpack(plane))


def test_pack_unpack_worker_stacked():
    """Leaves with a leading (R,) worker axis round-trip per worker."""
    params = _params("biglstm")
    R = 3
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), params)
    fs = FlatSpace.build(stacked, batch_ndim=1)
    plane = fs.pack(stacked)
    assert plane.shape == (R, fs.plane_size)
    _assert_tree_bitwise(stacked, fs.unpack(plane))
    # each worker row is that worker's own plane
    fs0 = FlatSpace.build(params, batch_ndim=0)
    np.testing.assert_array_equal(np.asarray(plane[1]),
                                  np.asarray(fs0.pack(params)))


def test_unpack_dtype_override_for_state_planes():
    """b2/residual planes share the param geometry but stay fp32."""
    params = _params("qwen2-7b")
    fs = FlatSpace.build(params, batch_ndim=0)
    b2 = jax.tree_util.tree_map(
        lambda p: jnp.full(p.shape, 2.0, jnp.float32), params)
    out = fs.unpack(fs.pack(b2), dtype=jnp.float32)
    _assert_tree_bitwise(b2, out)


# --------------------------------------------------------------------------- #
# layout properties: buckets, alignment, sidecars
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ARCHS)
def test_dtype_buckets_are_contiguous(arch):
    fs = FlatSpace.build(_params(arch), batch_ndim=0)
    ranges = fs.bucket_ranges()
    names = [n for n, _, _ in ranges]
    assert len(names) == len(set(names)), f"split buckets: {names}"
    assert ranges[0][1] == 0 and ranges[-1][2] == fs.plane_size
    for (_, _, stop), (_, start, _) in zip(ranges, ranges[1:]):
        assert stop == start
    # slots aligned -> every slot offset is a whole number of tiles
    for slot in fs.slots:
        assert slot.offset % fs.align == 0
        assert slot.padded % fs.align == 0


def test_round16_sidecars_follow_slot_dtypes():
    fs = FlatSpace.build(_params("biglstm"), batch_ndim=0)
    elems = fs.round16_elems()
    assert elems.shape == (fs.plane_size,)
    for slot in fs.slots:
        seg = elems[slot.offset:slot.offset + slot.padded]
        want = jnp.dtype(slot.dtype).itemsize == 2
        assert seg.all() == want and seg.any() == want
    rows = FlatSpace.rows_sidecar(elems, 128)
    assert rows.shape == (fs.plane_size // 128, 1)
    np.testing.assert_array_equal(rows[:, 0] > 0, elems[::128])


def test_pad_accounting():
    fs = FlatSpace.build(_params("biglstm"), batch_ndim=0)
    assert fs.pad_elems == fs.plane_size - fs.n_real
    assert fs.n_real == sum(s.size for s in fs.slots)
    assert fs.n_leaves == len(jax.tree_util.tree_leaves(_params("biglstm")))


def test_non_float_leaves_rejected():
    with pytest.raises(ValueError, match="non-float"):
        FlatSpace.build({"a": jnp.zeros((4,), jnp.int32)})


# --------------------------------------------------------------------------- #
# the single-collective mean
# --------------------------------------------------------------------------- #
def test_mean_planes_matches_per_leaf_bf16_mean():
    """The identity the ONE-collective flat sync leans on: jnp.mean over a
    bf16 leaf accumulates in fp32 and rounds the quotient — exactly what
    mean_planes' fp32 mean + bf16 re-round computes."""
    R, n = 8, 4099
    x16 = (jax.random.normal(jax.random.PRNGKey(0), (R, n), jnp.float32)
           .astype(jnp.bfloat16))

    @jax.jit
    def per_leaf(x):
        return jnp.mean(x, axis=0, keepdims=True).astype(jnp.float32)

    @jax.jit
    def flat(x32):
        return mean_planes(x32, np.ones(n, np.bool_))

    np.testing.assert_array_equal(
        np.asarray(jnp.broadcast_to(per_leaf(x16), (R, n))),
        np.asarray(flat(x16.astype(jnp.float32))))


def test_mean_planes_f32_passthrough():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 515), jnp.float32)
    m = mean_planes(x, np.zeros(515, np.bool_))
    np.testing.assert_array_equal(
        np.asarray(m),
        np.asarray(jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                    x.shape)))


# --------------------------------------------------------------------------- #
# optimizer-state adapters (checkpoint round-trips)
# --------------------------------------------------------------------------- #
def test_opt_state_adapters_roundtrip():
    params = _params("biglstm")
    fs = FlatSpace.build(params, batch_ndim=0)
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"step": jnp.zeros((), jnp.int32),
             "tprime": jnp.ones((), jnp.int32),
             "b2_sync": jax.tree_util.tree_map(lambda z: z + 1.5, zeros),
             "b2_local": jax.tree_util.tree_map(lambda z: z + 2.5, zeros),
             "res_params": zeros}
    flat = pack_opt_state(fs, state)
    assert flat["step"] is state["step"]          # scalars pass through
    assert flat["b2_sync"].shape == (fs.plane_size,)
    back = unpack_opt_state(fs, flat)
    for k in ("b2_sync", "b2_local", "res_params"):
        _assert_tree_bitwise(state[k], back[k])


def test_flat_abstract_matches_packed_shapes():
    params = _params("biglstm")
    fs = FlatSpace.build(params, batch_ndim=0)
    state = {"step": jnp.zeros((), jnp.int32),
             "b2_local": jax.tree_util.tree_map(
                 lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    plane_abs, state_abs = flat_abstract(fs, params, state)
    packed = pack_opt_state(fs, state)
    assert plane_abs.shape == fs.pack(params).shape
    assert state_abs["b2_local"].shape == packed["b2_local"].shape
    assert state_abs["step"].shape == state["step"].shape


def test_is_flat_checkpoint_key_detection():
    assert is_flat_checkpoint(["#0", "#1/step", "#1/b2_local"])
    assert not is_flat_checkpoint(["#0/embed", "#1/step", "#2/since"])
