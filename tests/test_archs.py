"""Per-architecture smoke tests (assignment requirement).

Every assigned architecture instantiates a REDUCED family member
(2 layers, d_model<=512, <=4 experts) and runs one forward + one train step
on CPU, asserting output shapes and the absence of NaNs. The full-size
configs are exercised via the dry-run only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, OptimizerConfig, ShapeConfig, get_arch,
                           reduced)
from repro.data import SyntheticLM, make_train_batch
from repro.launch.steps import build_train_programs
from repro.launch.mesh import resolve_plan
from repro.models import build_model

SEQ, BATCH, VOCAB = 64, 4, 512


def _shape():
    return ShapeConfig(name="smoke", seq_len=SEQ, global_batch=BATCH,
                       kind="train")


def _batch(cfg, seed=0):
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=SEQ, n_workers=1,
                     seed=seed)
    return {k: jnp.asarray(v) for k, v in
            make_train_batch(cfg, _shape(), ds, 0).items()}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finiteness(arch):
    cfg = reduced(get_arch(arch), vocab=VOCAB)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.logits_fn(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss)), arch
    # random init should predict near-uniform: loss ~ log(V)
    assert float(loss) < np.log(cfg.vocab_size) * 1.5 + 1.0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch):
    cfg = reduced(get_arch(arch), vocab=VOCAB)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt_cfg = OptimizerConfig(name="local_adaalter", lr=0.3, H=2,
                              warmup_steps=0)
    with mesh:
        plan = resolve_plan(cfg, mesh, optimizer="local_adaalter")
        programs = build_train_programs(cfg, _shape(), opt_cfg, mesh, plan)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(0))
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=SEQ,
                         n_workers=max(programs.n_workers, 1), seed=0)
        batch = jax.tree_util.tree_map(jnp.asarray, make_train_batch(
            cfg, _shape(), ds, 0,
            n_workers=programs.n_workers if programs.is_local else 0))
        before = [np.asarray(leaf, np.float32)
                  for leaf in jax.tree_util.tree_leaves(params)]
        p1, s1, metrics = programs.local_step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"])), arch
        after = [np.asarray(leaf, np.float32)
                 for leaf in jax.tree_util.tree_leaves(p1)]
        for leaf in after:
            assert np.isfinite(leaf).all(), arch
        # params actually moved
        assert any(a.size > 1 and not np.array_equal(a, b)
                   for a, b in zip(before, after)), arch
