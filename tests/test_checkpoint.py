"""Checkpoint store: roundtrip (incl. bfloat16), latest-step, mismatch errors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (checkpoint_keys, latest_step,
                              restore_checkpoint, save_checkpoint)


@pytest.fixture
def state():
    return {
        "params": {
            "w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)),
                             jnp.bfloat16),
            "blocks": [{"a": jnp.arange(5.0)}, {"a": jnp.ones(5)}],
        },
        "opt": {"step": jnp.int32(7),
                "b2": {"w": jnp.full((8, 4), 2.0)},
                "tprime": jnp.int32(3)},
    }


def test_roundtrip_exact(tmp_path, state):
    d = str(tmp_path)
    save_checkpoint(d, 7, state)
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: state))
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)
    # dtypes preserved (bfloat16 survives the npz void-dtype trap)
    assert restored["params"]["w"].dtype == jnp.bfloat16


def test_latest_step_picks_max(tmp_path, state):
    d = str(tmp_path)
    assert latest_step(d) is None
    for s in (5, 20, 10):
        save_checkpoint(d, s, state)
    assert latest_step(d) == 20
    _, step = restore_checkpoint(d, jax.eval_shape(lambda: state))
    assert step == 20


def test_checkpoint_keys_reads_manifest(tmp_path, state):
    d = str(tmp_path)
    with pytest.raises(FileNotFoundError):
        checkpoint_keys(d)
    save_checkpoint(d, 4, (state, {"extra": jnp.zeros(2)}))
    keys = checkpoint_keys(d)
    assert "#0/opt/step" in keys and "#1/extra" in keys
    # structure sniffing without loading arrays: how train_loop detects
    # pre-SyncState 2-tuple checkpoints
    assert not any(k.startswith("#2/") for k in keys)


def test_structure_mismatch_raises(tmp_path, state):
    d = str(tmp_path)
    save_checkpoint(d, 1, state)
    bad = dict(state)
    bad["extra"] = jnp.zeros(3)
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(d, jax.eval_shape(lambda: bad))


def test_shape_mismatch_raises(tmp_path, state):
    d = str(tmp_path)
    save_checkpoint(d, 1, state)
    bad = jax.eval_shape(lambda: state)
    bad["params"]["w"] = jax.ShapeDtypeStruct((9, 4), jnp.bfloat16)
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(d, bad)


def test_overwrite_same_step(tmp_path, state):
    d = str(tmp_path)
    save_checkpoint(d, 3, state)
    state2 = jax.tree_util.tree_map(
        lambda x: x + 1 if x.dtype != jnp.bfloat16 else x, state)
    save_checkpoint(d, 3, state2)
    restored, _ = restore_checkpoint(d, jax.eval_shape(lambda: state))
    np.testing.assert_array_equal(np.asarray(restored["opt"]["step"]), 8)
