"""Observability subsystem (repro.obs): metrics registry semantics, JSONL +
Prometheus export formats, the null-registry zero-overhead contract, the
sync-health probe on a real instrumented run (same numbers on the trace
spans and in the metrics rows), and the bench-regression gate's stated
tolerances including its nonzero exit on an injected regression.
"""
import json
import math
import os

import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       NULL_REGISTRY)
from repro.obs.regress import (compare_rows, field_tolerance, main as
                               regress_main)


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #
def test_counter_is_monotone():
    r = MetricsRegistry()
    c = r.counter("steps_total")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_gauge_keeps_last_value_and_tags_nonfinite():
    r = MetricsRegistry()
    g = r.gauge("loss")
    g.set(2.0)
    g.set(1.5)
    assert g.value == 1.5
    g.set(float("inf"))
    assert math.isnan(g.value)


def test_histogram_summary_quantiles():
    r = MetricsRegistry()
    h = r.histogram("step_time_s")
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
    assert s["sum"] == pytest.approx(5050.0)
    assert 45 <= s["p50"] <= 55 and 85 <= s["p90"] <= 95
    assert s["p99"] >= 98


def test_labeled_metrics_are_distinct():
    r = MetricsRegistry()
    r.gauge("b2", bucket="float32", q="p50").set(1.0)
    r.gauge("b2", bucket="bfloat16", q="p50").set(2.0)
    snap = r.snapshot()["metrics"]
    assert snap["b2{bucket=float32,q=p50}"] == 1.0
    assert snap["b2{bucket=bfloat16,q=p50}"] == 2.0


def test_kind_collision_raises():
    r = MetricsRegistry()
    r.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("x")


def test_collect_appends_rows_and_jsonl(tmp_path):
    path = str(tmp_path / "m.jsonl")
    r = MetricsRegistry(labels={"arch": "t"})
    r.open_jsonl(path)
    r.counter("steps_total").inc()
    r.gauge("loss").set(3.0)
    r.collect(0)
    r.gauge("loss").set(float("nan"))       # must stay strict-RFC JSON
    r.collect(1)
    r.close()
    lines = [json.loads(l) for l in open(path)]
    assert lines[0] == {"stream": "repro.obs.metrics", "labels": {"arch": "t"}}
    assert lines[1]["step"] == 0 and lines[1]["metrics"]["loss"] == 3.0
    assert lines[2]["metrics"]["loss"] is None      # NaN -> null
    assert len(r.rows) == 2


def test_prom_text_format(tmp_path):
    r = MetricsRegistry(labels={"run": "a b"})
    r.gauge("loss", help="train loss").set(2.5)
    r.counter("steps_total").inc(3)
    r.histogram("step_time_s").observe(1.0)
    txt = r.prom_text()
    assert "# HELP repro_loss train loss" in txt
    assert "# TYPE repro_loss gauge" in txt
    assert 'repro_loss{run="a b"} 2.5' in txt
    assert "# TYPE repro_steps_total counter" in txt
    assert "# TYPE repro_step_time_s summary" in txt
    assert 'quantile="0.5"' in txt
    assert 'repro_step_time_s_count{run="a b"} 1' in txt
    # atomic write leaves no temp file behind
    path = str(tmp_path / "m.prom")
    r.write_prom(path)
    assert open(path).read() == txt
    assert not os.path.exists(path + ".tmp")


def test_null_registry_is_free_and_falsy():
    assert not NULL_REGISTRY
    # every instrument is the shared no-op; nothing is recorded
    NULL_REGISTRY.counter("a").inc()
    NULL_REGISTRY.gauge("b").set(1.0)
    NULL_REGISTRY.histogram("c").observe(1.0)
    assert NULL_REGISTRY.collect(0) == {}
    assert NULL_REGISTRY.snapshot() == {"metrics": {}, "hists": {}}
    NULL_REGISTRY.open_jsonl("/nonexistent/dir/never_opened.jsonl")
    NULL_REGISTRY.write_prom("/nonexistent/dir/never_written.prom")
    assert isinstance(NULL_REGISTRY.counter("a"), type(NULL_REGISTRY.gauge("b")))


# --------------------------------------------------------------------------- #
# instrumented run: probe + registry + trace report the same numbers
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def metrics_run(tmp_path_factory):
    from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
    from repro.configs.base import SyncConfig
    from repro.launch.train import train_loop
    from repro.trace import Trace
    tmp = tmp_path_factory.mktemp("obs")
    cfg = reduced(get_arch("biglstm"), vocab=128)
    shape = ShapeConfig(name="obs", seq_len=32, global_batch=8, kind="train")
    opt = OptimizerConfig.from_sync(
        SyncConfig(compression="int8"), name="local_adaalter", lr=0.5, H=3,
        warmup_steps=5)
    mpath, tpath = str(tmp / "m.jsonl"), str(tmp / "t.json")
    res = train_loop(cfg, shape, opt, steps=9, verbose=False,
                     trace_out=tpath, metrics_out=mpath)
    rows = [json.loads(l) for l in open(mpath)]
    return res, rows, Trace.load(tpath), mpath


def test_metrics_stream_has_one_row_per_step(metrics_run):
    res, rows, _, _ = metrics_run
    assert rows[0]["stream"] == "repro.obs.metrics"
    body = rows[1:]
    assert [r["step"] for r in body] == list(range(9))
    for r in body:
        m = r["metrics"]
        assert "loss" in m and "grad_norm" in m
        assert m["steps_total"] == r["step"] + 1
        assert m["wire_compression_ratio"] == pytest.approx(3.938, abs=0.1)
        assert any(k.startswith("b2{") for k in m)


def test_sync_round_probes_only_on_sync_steps(metrics_run):
    res, rows, _, _ = metrics_run
    body = rows[1:]
    first_sync = res.sync_steps[0]
    pre = body[first_sync - 1]["metrics"]
    at = body[first_sync]["metrics"]
    assert not any(k.startswith("ef_residual_norm") for k in pre)
    assert any(k.startswith("ef_residual_norm") for k in at)
    assert at["quant_mse"] > 0                 # int8 is lossy
    assert at["sync_rounds_total"] == 1
    assert at["wire_bytes_total"] == pytest.approx(
        at["round_wire_bytes"])


def test_trace_and_metrics_report_same_numbers(metrics_run):
    # satellite contract: ONE probe feeds both exports — per step, the
    # span's grad_norm/b2 equal the metrics row's gauges exactly
    _, rows, trace, _ = metrics_run
    by_step = {r["step"]: r["metrics"] for r in rows[1:]}
    for s in trace.by_name("local_step"):
        m = by_step[s.step]
        assert s.args["grad_norm"] == m["grad_norm"]
        for bucket, qs in s.args["b2"].items():
            for q, v in qs.items():
                assert m[f"b2{{bucket={bucket},q={q}}}"] == v
        assert s.args["loss"] == m["loss"]


def test_prom_file_written_next_to_jsonl(metrics_run):
    _, _, _, mpath = metrics_run
    ppath = mpath[:-len(".jsonl")] + ".prom"
    txt = open(ppath).read()
    assert "# TYPE repro_loss gauge" in txt
    assert "repro_final_loss" in txt
    assert "# TYPE repro_step_time_s summary" in txt


def test_uninstrumented_config_has_no_grad_norm():
    # obs_metrics=False: the emission is not compiled in at all
    from repro.configs import OptimizerConfig
    assert OptimizerConfig().obs_metrics is False


# --------------------------------------------------------------------------- #
# bench-regression gate
# --------------------------------------------------------------------------- #
def test_field_tolerances_are_the_stated_table():
    assert field_tolerance("us_per_call") is None          # timing: skipped
    assert field_tolerance("wall_s") is None
    assert field_tolerance("trace") is None                # path: skipped
    assert field_tolerance("final_loss") == 0.02
    assert field_tolerance("sync_count") == 0.35
    assert field_tolerance("launches") == 1e-6             # modeled: strict
    assert field_tolerance("modeled_hbm_mb") == 1e-6
    # nested paths match on the LEAF name
    assert field_tolerance("wall.ms_per_step") is None
    assert field_tolerance("per_leaf.collectives") == 1e-6
    # structural field whose name merely CONTAINS 'ms_per' must stay gated
    assert field_tolerance("pad_elems_per_step") == 1e-6


def _rows(**over):
    row = {"bench": "b", "method": "m", "launches": 3, "final_loss": 2.0,
           "us_per_call": 10.0, "gate_ok": True, "sync_count": 10,
           "sizes": [1, 2, 3]}
    row.update(over)
    return [row]


def test_compare_rows_clean_and_timing_ignored():
    assert compare_rows(_rows(), _rows(us_per_call=99.0)) == []


def test_compare_rows_catches_modeled_drift():
    fails = compare_rows(_rows(), _rows(launches=4))
    assert len(fails) == 1 and "launches" in fails[0]["reason"]


def test_compare_rows_loss_tolerance():
    assert compare_rows(_rows(), _rows(final_loss=2.0 * 1.015)) == []
    assert compare_rows(_rows(), _rows(final_loss=2.2))


def test_compare_rows_schedule_tolerance():
    assert compare_rows(_rows(), _rows(sync_count=12)) == []      # +20%
    assert compare_rows(_rows(), _rows(sync_count=20))            # +100%


def test_compare_rows_boolean_gate_and_lists():
    assert compare_rows(_rows(), _rows(gate_ok=False))
    assert compare_rows(_rows(), _rows(sizes=[1, 2, 4]))
    assert compare_rows(_rows(), _rows(sizes=[1, 2]))


def test_compare_rows_missing_row_is_a_regression():
    fails = compare_rows(_rows(), [])
    assert fails and "missing" in fails[0]["reason"]
    # extra fresh rows are fine (new coverage needs no baseline)
    assert compare_rows(_rows(), _rows() + [{"bench": "new", "x": 1}]) == []


def test_regress_cli_clean_then_injected_regression(tmp_path, capsys):
    base = tmp_path / "baselines"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    rows = _rows()
    (base / "BENCH_x.json").write_text(json.dumps(rows))
    (fresh / "BENCH_x.json").write_text(json.dumps(rows))
    regress_main(["--baselines", str(base), "--fresh", str(fresh)])
    assert "ok" in capsys.readouterr().out

    bad = _rows(launches=6, us_per_call=999.0)     # timing drift must NOT trip
    (fresh / "BENCH_x.json").write_text(json.dumps(bad))
    report = tmp_path / "report.json"
    with pytest.raises(SystemExit) as e:
        regress_main(["--baselines", str(base), "--fresh", str(fresh),
                      "--report", str(report)])
    assert e.value.code == 1
    rep = json.loads(report.read_text())
    assert rep["failures"] and "launches" in rep["failures"][0]["reason"]
    assert not any("us_per_call" in f["reason"] for f in rep["failures"])


def test_regress_cli_allow_missing(tmp_path, capsys):
    base = tmp_path / "baselines"
    base.mkdir()
    (base / "BENCH_x.json").write_text(json.dumps(_rows()))
    regress_main(["--baselines", str(base), "--fresh", str(tmp_path),
                  "--allow-missing"])
    assert "skipped" in capsys.readouterr().out
    # without --allow-missing the absent fresh file IS a failure
    with pytest.raises(SystemExit):
        regress_main(["--baselines", str(base), "--fresh", str(tmp_path)])
