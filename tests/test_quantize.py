"""Quantized sync subsystem: kernel-vs-oracle, error feedback, accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig
from repro.core import optimizers as opt
from repro.core.comm import payload_bytes, sync_bytes_per_step
from repro.kernels.quantize import (BLOCK, dequantize, fake_quantize,
                                    quantize)
from repro.kernels.ref import dequantize_blocks_ref, quantize_blocks_ref

SHAPES = [
    (100,),                  # sub-block 1-D (padded path)
    (256,),                  # exactly one block
    (3000,),                 # non-multiple 1-D
    (4, 1000),               # batched leaf (worker axis)
    (2, 3, 130),             # 3-D leaf
    (600, 256),              # > one grid tile when tile_blocks is small
]


def _mk(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return (x * 0.5).astype(dtype)


# --------------------------------------------------------------------------- #
# kernel == oracle
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_matches_oracle(shape, dtype):
    x = _mk(shape, dtype, sum(shape) + len(shape))
    bnd = 1 if len(shape) > 1 else 0
    qk, sk = quantize(x, batch_ndim=bnd, use_pallas=True)
    qr, sr = quantize(x, batch_ndim=bnd, use_pallas=False)
    assert qk.dtype == jnp.int8 and sk.dtype == jnp.float32
    # scales may differ by 1 ulp (interpret-mode fusion); q by 1 LSB then
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    assert np.abs(np.asarray(qk, np.int32) - np.asarray(qr, np.int32)).max() <= 1
    yk = dequantize(qk, sk, x.shape, batch_ndim=bnd, use_pallas=True)
    yr = dequantize(qr, sr, x.shape, batch_ndim=bnd, use_pallas=False)
    # a 1-LSB q difference moves the dequant by at most one scale step
    step = float(np.max(np.asarray(sr)))
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-5, atol=step * 1.01)


@pytest.mark.parametrize("shape", SHAPES)
def test_roundtrip_error_bounded(shape):
    """|x − dq(q(x))| ≤ scale/2 per block (≤ 1e-2 for unit-scale inputs)."""
    x = _mk(shape, jnp.float32, 7)
    y = fake_quantize(x, batch_ndim=1 if len(shape) > 1 else 0)
    err = np.abs(np.asarray(y) - np.asarray(x)).max()
    bound = float(np.abs(np.asarray(x)).max()) / 253.0   # scale/2 = amax/254
    assert err <= bound * 1.01, (err, bound)
    assert err <= 1e-2


def test_oracle_blocks_zero_and_extremes():
    x = jnp.concatenate([jnp.zeros((1, BLOCK)),                 # all-zero block
                         jnp.full((1, BLOCK), -3.0),            # constant block
                         jnp.eye(1, BLOCK) * 1e4])              # one spike
    q, s = quantize_blocks_ref(x)
    assert np.all(np.asarray(q[0]) == 0) and float(s[0, 0]) == 0.0
    assert np.all(np.asarray(q[1]) == -127)
    y = dequantize_blocks_ref(q, s)
    np.testing.assert_allclose(np.asarray(y[1]), -3.0, rtol=1e-6)
    assert float(y[2, 0]) == pytest.approx(1e4, rel=1e-6)


# --------------------------------------------------------------------------- #
# compressed_sync: error feedback + identity guarantees
# --------------------------------------------------------------------------- #
def test_no_compression_returns_base():
    base = opt.local_adaalter(H=4)
    assert opt.compressed_sync(base, "") is base
    o = opt.make_optimizer(OptimizerConfig(name="local_adaalter"))
    assert "res_params" not in o.init({"w": jnp.zeros(4)})


def test_unknown_compression_raises():
    with pytest.raises(ValueError, match="compression"):
        opt.compressed_sync(opt.local_adaalter(), "fp4")


def test_compression_rejected_for_sync_optimizers():
    """Silently ignoring it would misreport comm volume ~4x (train_loop
    feeds cfg.compression straight into sync_bytes_per_step)."""
    for name in ("sgd", "adagrad", "adaalter"):
        with pytest.raises(ValueError, match="local optimizer"):
            opt.make_optimizer(OptimizerConfig(name=name, compression="int8"))


def test_residual_is_exact_quantization_error():
    """After a sync, wire + residual must reconstruct params + old residual."""
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=1, warmup_steps=0,
        compression="int8"))
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=500),
                               jnp.float32)}
    state = o.init(params)
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=500) * 0.1,
                          jnp.float32)}
    params, state = o.local_step(g, state, params)
    pre_sync = np.asarray(params["w"]).copy()
    synced, state = o.sync(params, state)       # identity mean_fn (1 worker)
    # error-feedback identity: sent value + residual == true value
    np.testing.assert_allclose(
        np.asarray(synced["w"]) + np.asarray(state["res_params"]["w"]),
        pre_sync, rtol=0, atol=1e-6)
    # residuals bounded by half a quantization step
    amax = np.abs(pre_sync).max()
    assert np.abs(np.asarray(state["res_params"]["w"])).max() <= amax / 253.0


def test_local_step_preserves_residuals_and_matches_base():
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=4, warmup_steps=0,
        compression="int8"))
    base = opt.local_adaalter(lr=0.3, H=4, warmup_steps=0)
    params = {"w": jnp.ones(300)}
    s, sb = o.init(params), base.init(params)
    res_marker = jax.tree_util.tree_map(lambda z: z + 7.0, s["res_params"])
    s["res_params"] = res_marker
    g = {"w": jnp.full(300, 0.1)}
    (p1, s1), (p2, s2) = o.local_step(g, s, params), base.local_step(g, sb, params)
    # local steps are communication-free: identical to the base optimizer
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
    np.testing.assert_array_equal(np.asarray(s1["b2_local"]["w"]),
                                  np.asarray(s2["b2_local"]["w"]))
    # ... and the residuals ride along untouched
    np.testing.assert_array_equal(np.asarray(s1["res_params"]["w"]),
                                  np.asarray(res_marker["w"]))


def test_b2_sync_stays_nonnegative():
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=1, warmup_steps=0,
        compression="int8", b0=0.01))
    params = {"w": jnp.linspace(-1.0, 1.0, 512)}
    state = o.init(params)
    for t in range(3):
        g = {"w": jnp.sin(jnp.arange(512.0) + t) * 0.01}
        params, state = o.local_step(g, state, params)
        params, state = o.sync(params, state)
    assert float(jnp.min(state["b2_sync"]["w"])) >= 0.0


def test_compressed_convergence_tracks_uncompressed():
    """Toy non-IID quadratic, 2 workers: int8+EF within 20% of fp32 sync."""
    n, d, H, T = 2, 512, 4, 64
    target = np.random.default_rng(0).normal(size=d).astype(np.float32)

    def mean_fn(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                       x.shape), tree)

    def run(compression):
        o = opt.make_optimizer(OptimizerConfig(
            name="local_adaalter", lr=0.3, H=H, warmup_steps=0,
            compression=compression))
        params = {"w": jnp.zeros((n, d), jnp.float32)}
        state = jax.vmap(o.init)(params)
        vstep = jax.vmap(o.local_step)
        rng = np.random.default_rng(1)
        for t in range(1, T + 1):
            g = (np.asarray(params["w"]) - target[None]
                 + rng.normal(size=(n, d)) * 0.1)
            params, state = vstep({"w": jnp.asarray(g, jnp.float32)},
                                  state, params)
            if t % H == 0:
                params, state = o.sync(params, state, mean_fn)
        return float(np.mean((np.asarray(params["w"]) - target[None]) ** 2))

    l_fp32, l_int8 = run(""), run("int8")
    assert l_int8 < l_fp32 * 1.2 + 1e-4, (l_fp32, l_int8)


def test_compressed_sync_pallas_path():
    """cfg.use_pallas routes quantization through the Pallas kernels."""
    o = opt.make_optimizer(OptimizerConfig(
        name="local_adaalter", lr=0.3, H=1, warmup_steps=0,
        compression="int8", use_pallas=True))
    params = {"w": jnp.asarray(np.random.default_rng(3).normal(size=600),
                               jnp.float32)}
    state = o.init(params)
    g = {"w": jnp.full(600, 0.05)}
    params, state = o.local_step(g, state, params)
    pre = np.asarray(params["w"]).copy()
    synced, state = o.sync(params, state)
    np.testing.assert_allclose(
        np.asarray(synced["w"]) + np.asarray(state["res_params"]["w"]),
        pre, rtol=0, atol=1e-6)


# --------------------------------------------------------------------------- #
# communication accounting
# --------------------------------------------------------------------------- #
def test_payload_bytes_model():
    assert payload_bytes(256) == 1024.0                       # fp32
    assert payload_bytes(256, compression="int8") == 260.0    # 256 + 1 scale
    with pytest.raises(ValueError, match="compression"):
        payload_bytes(256, compression="fp4")


def test_sync_bytes_compression_ratio():
    """int8 + per-256 fp32 scales must shrink 2P/H by ~4x (to ~P/2H)."""
    P, H = 10_000_000, 4
    full = sync_bytes_per_step("local_adaalter", P, H)
    comp = sync_bytes_per_step("local_adaalter", P, H, compression="int8")
    assert full / comp == pytest.approx(4.0 / (1.0 + 4.0 / 256))  # ~3.94
    assert comp == pytest.approx(2.0 * P * (1 + 4 / 256) / H)
