"""SyncEngine subsystem: composition, SyncState checkpointing, adaptive
mid-window restore (bit-identical schedule), grad-staleness drift metric,
SyncConfig back-compat aliases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.core import comm
from repro.core.sync_engine import (DRIFT_METRICS, SyncEngine, SyncState,
                                    make_sync_engine)
from repro.core.sync_policy import AdaptiveSyncPolicy, FixedHPolicy
from repro.core import optimizers as opt_lib
from repro.data import SyntheticLM, make_train_batch
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs
from repro.launch.train import make_cpu_mesh, train_loop

SHAPE = ShapeConfig(name="eng", seq_len=32, global_batch=8, kind="train")


def _cfg(vocab=128):
    return reduced(get_arch("biglstm"), vocab=vocab)


# --------------------------------------------------------------------------- #
# SyncConfig block + back-compat aliases
# --------------------------------------------------------------------------- #
def test_sync_config_built_from_aliases():
    cfg = OptimizerConfig(name="local_adaalter", sync_policy="adaptive",
                          sync_threshold=0.1, h_min=2, h_max=8,
                          compression="int8", compression_block=128,
                          drift_metric="grad_staleness", sync_fused=False)
    assert cfg.sync == SyncConfig(policy="adaptive", threshold=0.1, h_min=2,
                                  h_max=8, drift_metric="grad_staleness",
                                  compression="int8", block=128, fused=False)
    # aliases mirror the block
    assert cfg.sync_policy == "adaptive" and cfg.compression == "int8"
    assert cfg.compression_block == 128 and cfg.sync_fused is False


def test_sync_config_block_and_aliases_compose_with_replace():
    cfg = OptimizerConfig.from_sync(
        SyncConfig(policy="adaptive", threshold=0.5))
    assert cfg.sync_policy == "adaptive" and cfg.sync_threshold == 0.5
    # replace via an alias updates the block...
    c2 = dataclasses.replace(cfg, compression="bf16")
    assert c2.sync.compression == "bf16" and c2.sync.policy == "adaptive"
    # ... and swapping the whole block resets everything not overridden
    c3 = c2.with_sync(SyncConfig(compression="int8"))
    assert c3.sync_policy == "fixed_h" and c3.compression == "int8"
    assert c3.lr == cfg.lr                   # non-sync fields untouched


# --------------------------------------------------------------------------- #
# engine composition + accounting
# --------------------------------------------------------------------------- #
def test_make_sync_engine_composes_policy_and_codec():
    eng = make_sync_engine(OptimizerConfig(H=4), is_local=True)
    assert isinstance(eng.policy, FixedHPolicy) and eng.policy.H == 4
    assert eng.codec.name == "fp32" and not eng.wants_drift
    eng = make_sync_engine(
        OptimizerConfig(sync_policy="adaptive", sync_threshold=0.1,
                        compression="int8"), is_local=True, H=4)
    assert isinstance(eng.policy, AdaptiveSyncPolicy)
    assert eng.codec.name == "int8" and eng.wants_drift
    assert eng.codec.ef_roundtrip is not None          # fused by default
    eng = make_sync_engine(
        OptimizerConfig(compression="int8", sync_fused=False), is_local=True)
    assert eng.codec.ef_roundtrip is None


def test_engine_rejects_unknown_drift_metric():
    with pytest.raises(ValueError, match="drift_metric"):
        SyncEngine(FixedHPolicy(4), None, drift_metric="vibes")
    assert set(DRIFT_METRICS) == {"update_norm", "grad_staleness"}


def test_engine_accounting_matches_comm():
    P = 1_000_000
    eng = make_sync_engine(
        OptimizerConfig(name="local_adaalter", H=4, compression="int8"),
        is_local=True, H=4)
    assert eng.round_bytes(P) == comm.sync_payload_bytes(
        "local_adaalter", P, compression="int8")
    assert eng.modeled_bytes_per_step(P) == pytest.approx(
        eng.round_bytes(P) / 4)
    assert eng.grad_allreduce_bytes(P) == 4.0 * P
    # fused encode touches ~2.4x less HBM than the three-pass composition
    # (38n vs 16n bytes modeled in comm.ef_sync_hbm_bytes)
    ratio = (eng.encode_hbm_bytes(P, fused=False)
             / eng.encode_hbm_bytes(P, fused=True))
    assert 2.0 < ratio < 3.0
    # the HBM model describes the int8 pipeline only — other codecs must
    # not silently get its quantize/scales passes charged to them
    bf = make_sync_engine(
        OptimizerConfig(name="local_adaalter", compression="bf16"),
        is_local=True, H=4)
    with pytest.raises(ValueError, match="int8"):
        bf.encode_hbm_bytes(P)


def test_engine_schedule_delegates_to_policy():
    eng = make_sync_engine(OptimizerConfig(H=3), is_local=True, H=3)
    eng.reset(0)
    synced = []
    for step in range(9):
        s = eng.want_sync(step)
        eng.observe(step, s, {"drift": 0.0})
        if s:
            synced.append(step)
    assert synced == [2, 5, 8]
    assert eng.sync_count == 3 and eng.sync_steps == synced
    assert eng.name == "fixed_h"


# --------------------------------------------------------------------------- #
# SyncState: export/import + checkpoint round-trip
# --------------------------------------------------------------------------- #
def test_sync_state_roundtrips_host_state_exactly():
    eng = make_sync_engine(
        OptimizerConfig(sync_policy="adaptive", sync_threshold=1e9,
                        h_min=1, h_max=64), is_local=True, H=4)
    eng.reset(0)
    # accumulate an 'awkward' float64 drift sum a float32 cast would mangle
    for step in range(7):
        s = eng.want_sync(step)
        eng.observe(step, s, {"drift": 0.1 + 1e-12})
    st = eng.export_state()
    assert st.drift.dtype == np.float64 and st.since.dtype == np.int64
    eng2 = make_sync_engine(
        OptimizerConfig(sync_policy="adaptive", sync_threshold=1e9,
                        h_min=1, h_max=64), is_local=True, H=4)
    eng2.reset(7)
    eng2.import_state(st)
    assert eng2.policy.host_state() == eng.policy.host_state()  # bit-exact


def test_sync_state_is_checkpointable_pytree(tmp_path):
    state = ({"w": jnp.arange(5.0)}, SyncState.make(3, 0.7500000000000018))
    d = str(tmp_path)
    save_checkpoint(d, 11, state)
    like = ({"w": jnp.zeros(5)}, SyncState.make())
    restored, step = restore_checkpoint(d, like)
    assert step == 11
    _, sync = restored
    assert isinstance(sync, SyncState)
    assert float(sync.drift) == 0.7500000000000018       # float64 survives
    assert int(sync.since) == 3


def test_fixed_h_state_is_inert():
    eng = make_sync_engine(OptimizerConfig(H=4), is_local=True, H=4)
    eng.reset(0)
    st = eng.export_state()
    assert int(st.since) == 0 and float(st.drift) == 0.0
    eng.import_state(SyncState.make(3, 9.9))             # no-op for fixed_h
    assert eng.want_sync(3)                              # still (step+1)%H


# --------------------------------------------------------------------------- #
# host-side proof that restoring SyncState fixes the re-anchoring bug
# --------------------------------------------------------------------------- #
def _drive(policy, steps, drift, start=0, stop_at=None, state=None):
    if state is not None:
        policy.reset(start)
        policy.load_host_state(*state)
    else:
        policy.reset(start)
    synced = []
    for step in range(start, steps):
        if stop_at is not None and step == stop_at:
            return synced, policy.host_state()
        s = policy.want_sync(step)
        policy.observe(step, s, {"drift": drift[step]})
        if s:
            synced.append(step)
    return synced, policy.host_state()


def test_adaptive_restore_with_state_matches_uninterrupted():
    rng = np.random.default_rng(0)
    drift = rng.uniform(0.0, 0.2, size=40)
    mk = lambda: AdaptiveSyncPolicy(threshold=0.3, h_min=2, h_max=9)
    full, _ = _drive(mk(), 40, drift)
    # save mid-window at step 15 (not a sync step for this drift stream)
    assert 15 not in full
    _, saved = _drive(mk(), 40, drift, stop_at=15)
    resumed, _ = _drive(mk(), 40, drift, start=15, state=saved)
    assert resumed == [s for s in full if s >= 15]
    # without the saved state the window re-anchors and the schedule shifts
    reanchored, _ = _drive(mk(), 40, drift, start=15)
    assert reanchored != resumed


# --------------------------------------------------------------------------- #
# end-to-end: mid-window checkpoint restore under the adaptive policy
# --------------------------------------------------------------------------- #
def test_adaptive_midwindow_restore_bit_identical_schedule(tmp_path):
    """Save at a non-sync step, restore, and the subsequent sync schedule
    (and losses) must be identical to the uninterrupted run — the SyncState
    in the checkpoint resumes the exact drift accumulator and window
    position instead of re-anchoring."""
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, warmup_steps=5,
                          sync_policy="adaptive", sync_threshold=0.02,
                          h_min=2, h_max=6)
    full = train_loop(cfg, SHAPE, opt, steps=18, verbose=False)
    assert 8 not in full.sync_steps, \
        "calibrate the test: step 8 must fall mid-window"
    d = str(tmp_path / "ckpt")
    train_loop(cfg, SHAPE, opt, steps=9, checkpoint_dir=d,
               checkpoint_every=9, verbose=False)
    resumed = train_loop(cfg, SHAPE, opt, steps=18, checkpoint_dir=d,
                         checkpoint_every=100, verbose=False)
    assert resumed.start_step == 9
    assert resumed.sync_steps == [s for s in full.sync_steps if s >= 9]
    np.testing.assert_allclose(resumed.losses, full.losses[9:],
                               rtol=1e-5, atol=1e-5)
    assert resumed.sync_count == len(resumed.sync_steps)


def test_legacy_two_tuple_checkpoint_still_restores(tmp_path):
    """Pre-SyncState checkpoints (params, opt_state) restore through the
    fallback path; the adaptive window then re-anchors at the restore."""
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4, warmup_steps=5)
    mesh = make_cpu_mesh()
    plan = resolve_plan(cfg, mesh, optimizer=opt.name)
    with mesh:
        programs = build_train_programs(cfg, SHAPE, opt, mesh, plan)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(0))
    d = str(tmp_path / "legacy")
    save_checkpoint(d, 2, (params, opt_state))
    res = train_loop(cfg, SHAPE, opt, steps=6, checkpoint_dir=d,
                     verbose=False)
    assert res.start_step == 2 and res.steps == 4
    assert res.sync_steps == [3]          # fixed_h stays globally anchored
    assert np.isfinite(res.final_loss)


# --------------------------------------------------------------------------- #
# grad-staleness drift metric
# --------------------------------------------------------------------------- #
def test_with_grad_anchor_manages_leaf():
    base = opt_lib.local_adaalter(lr=0.3, H=4, warmup_steps=0)
    o = opt_lib.with_grad_anchor(base)
    params = {"w": jnp.ones(32)}
    state = o.init(params)
    assert "g_anchor" in state
    np.testing.assert_array_equal(np.asarray(state["g_anchor"]["w"]), 0.0)
    marker = {"w": jnp.full(32, 5.0)}
    state["g_anchor"] = marker
    g = {"w": jnp.full(32, 0.1)}
    params, state = o.local_step(g, state, params)
    np.testing.assert_array_equal(np.asarray(state["g_anchor"]["w"]), 5.0)
    params, state = o.sync(params, state)
    np.testing.assert_array_equal(np.asarray(state["g_anchor"]["w"]), 5.0)
    # the base numerics are untouched by the wrapper
    pb, sb = base.local_step(g, base.init({"w": jnp.ones(32)}),
                             {"w": jnp.ones(32)})
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(pb["w"]))


def test_make_optimizer_adds_anchor_only_for_staleness():
    staleness = OptimizerConfig(name="local_adaalter", sync_policy="adaptive",
                                drift_metric="grad_staleness")
    o = opt_lib.make_optimizer(staleness)
    assert "g_anchor" in o.init({"w": jnp.zeros(4)})
    for cfg in (OptimizerConfig(name="local_adaalter"),
                OptimizerConfig(name="local_adaalter",
                                sync_policy="adaptive")):
        assert "g_anchor" not in opt_lib.make_optimizer(cfg).init(
            {"w": jnp.zeros(4)})


def _run_program_steps(opt):
    cfg = _cfg()
    mesh = make_cpu_mesh()
    plan = resolve_plan(cfg, mesh, optimizer=opt.name)
    with mesh:
        programs = build_train_programs(cfg, SHAPE, opt, mesh, plan)
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=SHAPE.seq_len,
                         n_workers=programs.n_workers, seed=0, non_iid=True)
        batch = jax.tree_util.tree_map(jnp.asarray, make_train_batch(
            cfg, SHAPE, ds, 0, n_workers=programs.n_workers))
        # the programs donate (params, opt_state): init fresh for each call
        params, opt_state = programs.init_fn(jax.random.PRNGKey(0))
        _, s1, m1 = programs.local_step(params, opt_state, batch)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(0))
        _, s2, m2 = programs.sync_step(params, opt_state, batch)
    return s1, m1, s2, m2


def test_steps_emit_staleness_drift_and_reanchor():
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, warmup_steps=0,
                          sync_policy="adaptive", sync_threshold=0.01,
                          drift_metric="grad_staleness")
    s_local, m_local, s_sync, m_sync = _run_program_steps(opt)
    # anchor starts at 0 -> ||g - 0||^2 / ||g||^2 ~= 1 on the first step
    assert float(m_local["drift"]) == pytest.approx(1.0, rel=1e-3)
    # local steps keep the anchor; the sync step re-anchors it to fresh g
    anchor_local = np.asarray(
        jax.tree_util.tree_leaves(s_local["g_anchor"])[0])
    anchor_sync = np.asarray(
        jax.tree_util.tree_leaves(s_sync["g_anchor"])[0])
    assert np.abs(anchor_local).max() == 0.0
    assert np.abs(anchor_sync).max() > 0.0


def test_grad_staleness_end_to_end_respects_bounds():
    cfg = _cfg()
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, warmup_steps=5,
                          sync_policy="adaptive", sync_threshold=3.0,
                          h_min=2, h_max=6, drift_metric="grad_staleness")
    res = train_loop(cfg, SHAPE, opt, steps=18, verbose=False)
    assert res.sync_policy == "adaptive"
    gaps = np.diff([-1] + res.sync_steps)
    assert gaps.min() >= 2 and gaps.max() <= 6
    assert np.isfinite(res.final_loss)
