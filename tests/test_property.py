"""Property-based tests (hypothesis) on the system's algebraic invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import optimizers as opt
from repro.core.comm import sync_bytes_per_step
from repro.kernels.ref import fused_update_ref

_settings = dict(max_examples=25, deadline=None)

finite = st.floats(min_value=-10, max_value=10, allow_nan=False,
                   allow_infinity=False, width=32)
grad_arrays = hnp.arrays(np.float32, st.integers(1, 32).map(lambda n: (n,)),
                         elements=finite)


# --------------------------------------------------------------------------- #
# Invariant 1 (the paper's key trick): during local steps the denominator is
# identical on every worker — it depends only on the synced B² and t'.
# --------------------------------------------------------------------------- #
@settings(**_settings)
@given(st.integers(1, 6), st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_denominator_identical_across_workers(H, n, seed):
    rng = np.random.default_rng(seed)
    o = opt.local_adaalter(lr=0.3, eps=1.0, b0=1.0, H=H)
    params = {"w": jnp.broadcast_to(jnp.asarray(rng.normal(size=4),
                                                jnp.float32), (n, 4))}
    state = jax.vmap(o.init)(params)
    vstep = jax.vmap(o.local_step)
    for t in range(H):
        g = {"w": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)}
        params, state = vstep(g, state, params)
        b2s = np.asarray(state["b2_sync"]["w"])
        # every worker's b2_sync (the denominator base) identical:
        assert np.all(b2s == b2s[0])
        # ... while the local accumulators may differ (they carry G∘G):
        assert np.asarray(state["tprime"]).max() == t + 1


# --------------------------------------------------------------------------- #
# Invariant 2: local AdaAlter with H=1 and one worker == AdaAlter exactly.
# --------------------------------------------------------------------------- #
@settings(**_settings)
@given(grad_arrays, st.integers(0, 2**31 - 1))
def test_h1_single_worker_equals_adaalter(g0, seed):
    rng = np.random.default_rng(seed)
    d = g0.shape[0]
    x0 = rng.normal(size=d).astype(np.float32)
    grads = [g0] + [rng.normal(size=d).astype(np.float32) for _ in range(3)]

    a = opt.adaalter(lr=0.4, eps=1.0, b0=1.0)
    pa = {"w": jnp.asarray(x0)}
    sa = a.init(pa)
    l = opt.local_adaalter(lr=0.4, eps=1.0, b0=1.0, H=1)
    pl = {"w": jnp.asarray(x0)}
    sl = l.init(pl)
    for g in grads:
        gj = {"w": jnp.asarray(g)}
        sq = {"w": jnp.asarray(g) ** 2}
        pa, sa = a.update(gj, sq, sa, pa)
        pl, sl = l.local_step(gj, sl, pl)
        pl, sl = l.sync(pl, sl)
        np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pl["w"]),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(sa["b2"]["w"]),
                               np.asarray(sl["b2_sync"]["w"]), rtol=1e-6)


# --------------------------------------------------------------------------- #
# Invariant 3: the accumulator B² is monotone non-decreasing (AdaGrad family).
# --------------------------------------------------------------------------- #
@settings(**_settings)
@given(st.lists(grad_arrays, min_size=2, max_size=6))
def test_accumulator_monotone(grads):
    d = grads[0].shape[0]
    grads = [np.resize(g, d).astype(np.float32) for g in grads]
    o = opt.adaalter(lr=0.1, eps=1.0, b0=1.0)
    p = {"w": jnp.zeros(d)}
    s = o.init(p)
    prev = np.asarray(s["b2"]["w"]).copy()
    for g in grads:
        gj = {"w": jnp.asarray(g)}
        p, s = o.update(gj, {"w": gj["w"] ** 2}, s, p)
        cur = np.asarray(s["b2"]["w"])
        assert np.all(cur >= prev - 1e-7)
        prev = cur.copy()


# --------------------------------------------------------------------------- #
# Invariant 4: warm-up learning rate is monotone in t and capped at lr.
# --------------------------------------------------------------------------- #
@settings(**_settings)
@given(st.floats(1e-4, 2.0, allow_nan=False), st.integers(1, 1000),
       st.integers(0, 2000))
def test_warmup_monotone_capped(lr, warm, t):
    e_t = float(opt.warmup_lr(lr, jnp.int32(t), warm))
    e_t1 = float(opt.warmup_lr(lr, jnp.int32(t + 1), warm))
    assert e_t <= e_t1 + 1e-9
    assert e_t <= lr * (1 + 1e-6)
    if t >= warm:
        assert abs(e_t - lr) < 1e-6 * max(lr, 1)


# --------------------------------------------------------------------------- #
# Invariant 5: fused-update oracle == composition of the two paper lines.
# --------------------------------------------------------------------------- #
@settings(**_settings)
@given(hnp.arrays(np.float32, (16,), elements=finite),
       hnp.arrays(np.float32, (16,), elements=finite),
       st.floats(0.01, 1.0), st.integers(1, 8))
def test_fused_update_is_composition(x, g, eta, tprime):
    b2 = np.abs(np.random.default_rng(0).normal(size=16)).astype(np.float32) + 1
    extra = tprime * 1.0
    y, nb2 = fused_update_ref(jnp.asarray(x), jnp.asarray(g),
                              jnp.asarray(b2), jnp.asarray(b2), eta, extra)
    want_y = x - eta * g / np.sqrt(b2 + extra)
    want_b2 = b2 + g * g
    np.testing.assert_allclose(np.asarray(y), want_y, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(nb2), want_b2, rtol=1e-6)


# --------------------------------------------------------------------------- #
# Invariant 6: communication accounting matches the paper's 2/H claim.
# --------------------------------------------------------------------------- #
@settings(**_settings)
@given(st.integers(1, 10**9), st.integers(1, 64))
def test_comm_two_over_h(n_params, H):
    full = sync_bytes_per_step("adagrad", n_params)
    local = sync_bytes_per_step("local_adaalter", n_params, H)
    assert abs(local - 2 * full / H) < 1e-6 * max(full, 1)
