"""Adaptive (CADA-style) sync policy vs the paper's fixed H=4 schedule.

Trains Local AdaAlter on the same synthetic non-IID stream with
``sync_policy='fixed_h'`` (H=4) and with ``sync_policy='adaptive'`` under
both drift metrics — ``update_norm`` (relative per-step parameter movement)
and ``grad_staleness`` (CADA-proper ‖g_t − g_last_sync‖², relative) — and
reports, per run:

  sync_count               MEASURED syncs the policy triggered (from
                           ``TrainResult``, not the 2P/H formula);
  measured_comm_mb_per_step  sync_count · codec payload / steps;
  modeled_comm_mb_per_step   the static fixed-H formula, for contrast;
  final_loss               convergence on the non-IID stream.

Acceptance (asserted into the summary row): the adaptive policy triggers
FEWER syncs than fixed H=4 at a final loss within 1%. The defaults
(threshold=0.005, h_min=4, h_max=16) are calibrated so the drift trigger
genuinely fires — sync gaps vary between h_min and h_max over training —
rather than riding either bound.

  PYTHONPATH=src python -m benchmarks.bench_adaptive_sync [--out out.json]
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.codecs import CODEC_NAMES
from repro.launch.train import train_loop


def run(steps: int = 120, seq: int = 64, batch: int = 8,
        threshold: float = 0.005, h_min: int = 4, h_max: int = 16,
        staleness_threshold: float = 8.0,
        compression: str = "") -> List[Dict]:
    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="bench", seq_len=seq, global_batch=batch,
                        kind="train")
    common = dict(name="local_adaalter", lr=0.5, H=4, warmup_steps=40,
                  compression=compression)
    variants = {
        "fixed_h(H=4)": OptimizerConfig(**common),
        f"adaptive(update_norm,thr={threshold},h=[{h_min},{h_max}])":
            OptimizerConfig(**common, sync_policy="adaptive",
                            sync_threshold=threshold,
                            h_min=h_min, h_max=h_max),
        f"adaptive(grad_staleness,thr={staleness_threshold},"
        f"h=[{h_min},{h_max}])":
            OptimizerConfig(**common, sync_policy="adaptive",
                            sync_threshold=staleness_threshold,
                            drift_metric="grad_staleness",
                            h_min=h_min, h_max=h_max),
    }
    rows, results = [], {}
    for method, opt_cfg in variants.items():
        res = train_loop(cfg, shape, opt_cfg, steps=steps, verbose=False)
        key = (opt_cfg.sync.drift_metric
               if opt_cfg.sync_policy == "adaptive" else "fixed_h")
        results[key] = res
        gaps = [b - a for a, b in zip([-1] + res.sync_steps, res.sync_steps)]
        rows.append({
            "bench": "adaptive_sync",
            "method": method + (f"+{compression}" if compression else ""),
            "steps": res.steps,
            "sync_count": res.sync_count,               # measured
            "sync_steps": res.sync_steps,               # measured schedule
            "sync_gap_min": min(gaps) if gaps else 0,
            "sync_gap_max": max(gaps) if gaps else 0,
            "measured_comm_mb_per_step": round(
                res.comm_bytes_per_step / 1e6, 3),
            "modeled_comm_mb_per_step": round(
                res.comm_bytes_modeled / 1e6, 3),
            "final_loss": round(res.final_loss, 4),
        })
    fixed = results["fixed_h"]
    for metric in ("update_norm", "grad_staleness"):
        adapt = results[metric]
        delta = (abs(adapt.final_loss - fixed.final_loss)
                 / max(abs(fixed.final_loss), 1e-9))
        rows.append({
            "bench": "adaptive_sync(summary)",
            "method": f"adaptive({metric})_vs_fixed",
            "sync_reduction": round(fixed.sync_count
                                    / max(adapt.sync_count, 1), 2),
            "comm_reduction": round(
                fixed.comm_bytes_per_step
                / max(adapt.comm_bytes_per_step, 1e-9), 2),
            "loss_delta_frac": round(delta, 4),
            "fewer_syncs": adapt.sync_count < fixed.sync_count,
            "loss_within_1pct": delta < 0.01,
        })
    # the two drift statistics head-to-head on the same stream
    un, gs = results["update_norm"], results["grad_staleness"]
    rows.append({
        "bench": "adaptive_sync(drift_metric_comparison)",
        "method": "update_norm_vs_grad_staleness",
        "sync_count_update_norm": un.sync_count,
        "sync_count_grad_staleness": gs.sync_count,
        "final_loss_update_norm": round(un.final_loss, 4),
        "final_loss_grad_staleness": round(gs.final_loss, 4),
        "schedules_differ": un.sync_steps != gs.sync_steps,
    })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--threshold", type=float, default=0.005)
    ap.add_argument("--staleness-threshold", type=float, default=8.0,
                    help="adaptive trigger for drift_metric=grad_staleness "
                         "(the statistic is O(1)/step, vs O(0.001) for "
                         "update_norm, so its scale differs)")
    ap.add_argument("--h-min", type=int, default=4)
    ap.add_argument("--h-max", type=int, default=16)
    ap.add_argument("--compress", nargs="?", const="int8", default="",
                    choices=["", *CODEC_NAMES])
    ap.add_argument("--out", default="BENCH_adaptive_sync.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run(steps=args.steps, threshold=args.threshold, h_min=args.h_min,
               h_max=args.h_max,
               staleness_threshold=args.staleness_threshold,
               compression=args.compress)
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
