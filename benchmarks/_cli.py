"""Shared CLI scaffold for the bench modules.

Every ``bench_*`` module exposes a uniform ``--out`` JSON path (defaulting
to ``BENCH_<name>.json`` at the repo root, ``''`` skips); this is the one
place the print-rows + write-JSON contract lives.
"""
from __future__ import annotations

import json
from typing import Dict, List


def emit(rows: List[Dict], out: str) -> None:
    """Print the result rows and (unless ``out`` is empty) write them as
    JSON to ``out``."""
    for r in rows:
        print(r)
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {out}")
