"""Quantized sync: payload, kernel time, and convergence delta vs fp32.

Three measurements for the int8 + error-feedback sync path
(``OptimizerConfig.compression='int8'``):

  payload      modeled ``sync_bytes_per_step`` fp32 vs int8+scales — the
               ~4x shrink of the paper's 2P/H claim (to ~P/2H), plus the
               simulated all-reduce step time at paper scale;
  kernel       wall time of the jitted quantize/dequantize round-trip
               (Pallas interpret on CPU, Mosaic on TPU) vs the jnp oracle
               at a production-ish payload size;
  convergence  final loss of Local AdaAlter with and without compression on
               the 200-step synthetic non-IID stream (acceptance: within 5%).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.comm import FabricModel, step_time, sync_bytes_per_step
from repro.kernels.quantize import dequantize, fake_quantize, quantize
from repro.launch.train import train_loop
from repro.models.counting import count_params


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(steps: int = 200, seq: int = 64, batch: int = 8,
        workers: int = 8, n: int = 1 << 22) -> List[Dict]:
    rows = []

    # ---- payload model at paper scale ----------------------------------- #
    n_params = count_params(get_arch("biglstm"))
    fabric = FabricModel()
    raw_bytes = {}
    for comp in ("", "int8"):
        b = sync_bytes_per_step("local_adaalter", n_params, 4, compression=comp)
        t = step_time("local_adaalter", n_params, 0.1, workers, 4, fabric,
                      compression=comp)
        raw_bytes[comp] = b
        rows.append({
            "bench": "sync_compression(payload)",
            "method": f"local_adaalter-H4{'+' + comp if comp else ''}",
            "sync_mb_per_step": round(b / 1e6, 2),
            "sim_step_ms": round(t * 1e3, 3),
        })
    rows[-1]["payload_shrink"] = round(raw_bytes[""] / raw_bytes["int8"], 2)

    # ---- quantization kernel time at production-ish size ---------------- #
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)

    def roundtrip(use_pallas):
        def f(a):
            q, s = quantize(a, use_pallas=use_pallas)
            return dequantize(q, s, a.shape, use_pallas=use_pallas)
        return f

    bound = float(jnp.abs(x).max()) / 253.0    # scale/2 = amax/254, + slack
    pallas_name = ("pallas(interpret)" if jax.default_backend() != "tpu"
                   else "pallas(mosaic)")
    for m, use_pallas in [("oracle(jit)", False), (pallas_name, True)]:
        f = jax.jit(roundtrip(use_pallas))
        t = _time(f, x)
        err = float(jnp.abs(f(x) - x).max())   # each method's OWN numerics
        rows.append({
            "bench": "sync_compression(kernel)",
            "method": m, "elements": n,
            "us_per_roundtrip": round(t * 1e6, 1),
            "max_abs_err": round(err, 5),
            "err_within_bound": err <= bound,
        })

    # ---- convergence delta on the synthetic stream ---------------------- #
    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="bench", seq_len=seq, global_batch=batch,
                        kind="train")
    finals = {}
    for comp in ("", "int8"):
        opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4,
                              warmup_steps=40, compression=comp)
        res = train_loop(cfg, shape, opt, steps=steps, verbose=False)
        finals[comp] = res.final_loss
        rows.append({
            "bench": "sync_compression(convergence)",
            "method": f"local_adaalter-H4{'+' + comp if comp else ''}",
            "final_loss": round(res.final_loss, 4),
            "steps": steps,
            "sync_mb_per_step": round(res.comm_bytes_per_step / 1e6, 2),
        })
    delta = abs(finals["int8"] - finals[""]) / max(abs(finals[""]), 1e-9)
    rows[-1]["loss_delta_frac"] = round(delta, 4)
    rows[-1]["within_5pct"] = delta < 0.05
    return rows


if __name__ == "__main__":
    for r in run(steps=60):
        print(r)
