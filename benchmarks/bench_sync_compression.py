"""Quantized sync: payload, kernel time, fused sync round, convergence.

Four measurements for the int8 + error-feedback sync path
(``OptimizerConfig.compression='int8'``):

  payload      modeled ``sync_bytes_per_step`` fp32 vs int8+scales — the
               ~4x shrink of the paper's 2P/H claim (to ~P/2H), plus the
               simulated all-reduce step time at paper scale;
  kernel       wall time of the jitted quantize/dequantize round-trip
               (Pallas interpret on CPU, Mosaic on TPU) vs the jnp oracle
               at a production-ish payload size;
  fused_round  wall time + modeled HBM bytes of one full error-feedback
               sync-round encode (EF add + quantize + dequantize + residual
               update): the fused one-HBM-pass kernel
               (``kernels/sync_fused.py``) vs the three-pass composition it
               replaces — bitwise-identical outputs, ~2.4x less HBM traffic
               (``comm.ef_sync_hbm_bytes``);
  convergence  final loss of Local AdaAlter with and without compression on
               the 200-step synthetic non-IID stream (acceptance: within 5%).

  PYTHONPATH=src python -m benchmarks.bench_sync_compression \
      [--steps 60] [--n 4194304] [--out BENCH_sync_compression.json]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.codecs import get_codec
from repro.core.comm import (FabricModel, ef_sync_hbm_bytes, step_time,
                             sync_bytes_per_step)
from repro.core.sync_engine import ef_apply
from repro.kernels.quantize import dequantize, fake_quantize, quantize
from repro.launch.train import train_loop
from repro.models.counting import count_params


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))           # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(steps: int = 200, seq: int = 64, batch: int = 8,
        workers: int = 8, n: int = 1 << 22) -> List[Dict]:
    rows = []

    # ---- payload model at paper scale ----------------------------------- #
    n_params = count_params(get_arch("biglstm"))
    fabric = FabricModel()
    raw_bytes = {}
    for comp in ("", "int8"):
        b = sync_bytes_per_step("local_adaalter", n_params, 4, compression=comp)
        t = step_time("local_adaalter", n_params, 0.1, workers, 4, fabric,
                      compression=comp)
        raw_bytes[comp] = b
        rows.append({
            "bench": "sync_compression(payload)",
            "method": f"local_adaalter-H4{'+' + comp if comp else ''}",
            "sync_mb_per_step": round(b / 1e6, 2),
            "sim_step_ms": round(t * 1e3, 3),
        })
    rows[-1]["payload_shrink"] = round(raw_bytes[""] / raw_bytes["int8"], 2)

    # ---- quantization kernel time at production-ish size ---------------- #
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)

    def roundtrip(use_pallas):
        def f(a):
            q, s = quantize(a, use_pallas=use_pallas)
            return dequantize(q, s, a.shape, use_pallas=use_pallas)
        return f

    bound = float(jnp.abs(x).max()) / 253.0    # scale/2 = amax/254, + slack
    pallas_name = ("pallas(interpret)" if jax.default_backend() != "tpu"
                   else "pallas(mosaic)")
    for m, use_pallas in [("oracle(jit)", False), (pallas_name, True)]:
        f = jax.jit(roundtrip(use_pallas))
        t = _time(f, x)
        err = float(jnp.abs(f(x) - x).max())   # each method's OWN numerics
        rows.append({
            "bench": "sync_compression(kernel)",
            "method": m, "elements": n,
            "us_per_roundtrip": round(t * 1e6, 1),
            "max_abs_err": round(err, 5),
            "err_within_bound": err <= bound,
        })

    # ---- fused vs three-pass error-feedback sync round ------------------ #
    e = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32) * 0.01
    outs = {}
    for method, fused in [("three_pass", False), ("fused(one_pass)", True)]:
        codec = get_codec("int8", use_pallas=True, fused=fused)
        f = jax.jit(lambda t, r, c=codec: ef_apply(t, r, c, 0))
        t = _time(f, (x,), (e,))
        hbm = ef_sync_hbm_bytes(n, fused=fused)
        outs[method] = (t, hbm, f((x,), (e,)))
        rows.append({
            "bench": "sync_compression(fused_round)",
            "method": method, "elements": n,
            "us_per_round": round(t * 1e6, 1),
            "modeled_hbm_mb": round(hbm / 1e6, 2),
        })
    (t3, h3, o3), (t1, h1, o1) = outs["three_pass"], outs["fused(one_pass)"]
    rows[-1]["hbm_shrink"] = round(h3 / h1, 2)
    rows[-1]["speedup"] = round(t3 / t1, 2)
    rows[-1]["bitwise_equal"] = bool(
        np.array_equal(np.asarray(o3[0][0]), np.asarray(o1[0][0]))
        and np.array_equal(np.asarray(o3[1][0]), np.asarray(o1[1][0])))
    if jax.default_backend() != "tpu":
        # interpret-mode wall time tracks emulation overhead, not HBM
        # traffic — the modeled_hbm_mb column is the claim on hardware
        rows[-1]["note"] = "interpret-mode timing (CPU); compare hbm model"

    # ---- convergence delta on the synthetic stream ---------------------- #
    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="bench", seq_len=seq, global_batch=batch,
                        kind="train")
    finals = {}
    for comp in ("", "int8"):
        opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=4,
                              warmup_steps=40, compression=comp)
        res = train_loop(cfg, shape, opt, steps=steps, verbose=False)
        finals[comp] = res.final_loss
        rows.append({
            "bench": "sync_compression(convergence)",
            "method": f"local_adaalter-H4{'+' + comp if comp else ''}",
            "final_loss": round(res.final_loss, 4),
            "steps": steps,
            "sync_mb_per_step": round(res.comm_bytes_per_step / 1e6, 2),
        })
    delta = abs(finals["int8"] - finals[""]) / max(abs(finals[""]), 1e-9)
    rows[-1]["loss_delta_frac"] = round(delta, 4)
    rows[-1]["within_5pct"] = delta < 0.05
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=60,
                    help="convergence-section train steps")
    ap.add_argument("--n", type=int, default=1 << 22,
                    help="kernel/fused-round payload elements")
    ap.add_argument("--out", default="BENCH_sync_compression.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run(steps=args.steps, n=args.n)
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
