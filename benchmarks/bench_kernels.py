"""Kernel hot-spot benchmark: fused AdaAlter update vs the unfused lowering.

Measures (a) wall time on CPU of the jitted fused oracle vs the unfused
per-op sequence the naive optimizer emits, and (b) the HBM-traffic model
(bytes) of both lowerings via the HLO cost walker — the fused kernel's
claim is 4 reads + 2 writes vs 7 reads + 3 writes. Also allclose-checks the
Pallas kernel (interpret mode) against the oracle at a production-ish size.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import leaf_fused_update
from repro.kernels.ref import fused_update_ref
from repro.roofline.hlo_cost import hlo_cost


def _unfused(x, g, b2_sync, b2_local, eta, extra):
    """The op-by-op lowering a generic optimizer library would emit."""
    g32 = g.astype(jnp.float32)
    denom_sq = b2_sync + extra
    denom = jnp.sqrt(denom_sq)
    norm_g = g32 / denom
    upd = eta * norm_g
    y = (x.astype(jnp.float32) - upd).astype(x.dtype)
    sq = g32 * g32
    new_b2 = b2_local + sq
    return y, new_b2


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        y, b = fn(*args)
    y.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(n: int = 1 << 22) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (n,), jnp.float32).astype(jnp.bfloat16)
    g = (jax.random.normal(ks[1], (n,)) * 0.1).astype(jnp.bfloat16)
    bs = jnp.abs(jax.random.normal(ks[2], (n,))) + 1.0
    bl = bs + jnp.abs(jax.random.normal(ks[3], (n,))) * 0.1
    eta, extra = 0.5, 4.0

    fused = jax.jit(fused_update_ref)
    unfused = jax.jit(_unfused)
    t_fused = _time(fused, x, g, bs, bl, eta, extra)
    t_unfused = _time(unfused, x, g, bs, bl, eta, extra)
    t_eager = _time(lambda *a: _unfused(*a), x, g, bs, bl, eta, extra, iters=2)

    # XLA auto-fuses the jitted elementwise chain (verified: both lowerings
    # report identical HBM traffic), so the Pallas kernel's value on TPU is
    # *guaranteeing* the fusion across donation/layout boundaries. The
    # analytic traffic of the materialized (eager) sequence is the contrast.
    cost_f = hlo_cost(jax.jit(fused_update_ref).lower(x, g, bs, bl, eta, extra)
                      .compile().as_text())
    bpe = {"x": 2, "g": 2, "bs": 4, "bl": 4}
    eager_bytes = n * (  # 7 reads + 3 writes incl. materialized intermediates
        bpe["g"] + 4 + bpe["bs"] + 4 + 4 + bpe["x"] + 4 +   # reads
        4 + bpe["x"] + 4)                                    # writes
    cost_u = hlo_cost(jax.jit(_unfused).lower(x, g, bs, bl, eta, extra)
                      .compile().as_text())

    # Pallas (interpret) correctness at this size
    y_ref, b_ref = fused(x, g, bs, bl, eta, extra)
    y_pl, b_pl = leaf_fused_update(x, g, bs, bl, eta, extra, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pl, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(b_pl), np.asarray(b_ref),
                               rtol=1e-5, atol=1e-5)

    return [{
        "bench": "kernel(adaalter_fused_update)",
        "method": m, "elements": n,
        "us_per_call": round(t * 1e6, 1),
        "hbm_bytes_model": int(b),
        "pallas_interpret_allclose": True,
    } for m, t, b in [("fused(jit)", t_fused, cost_f.bytes),
                      ("unfused(jit,auto-fused)", t_unfused, cost_u.bytes),
                      ("unfused(eager,materialized)", t_eager, eager_bytes)]]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 22,
                    help="update payload elements")
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run(n=args.n)
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
