"""§Roofline table: renders the dry-run records (experiments/dryrun_baseline).

Not a measurement itself — aggregates the per-(arch x shape x mesh) JSON
records the dry-run wrote, one row per compiled program, so that
``python -m benchmarks.run`` reproduces the EXPERIMENTS.md table from the
artifacts. Skips silently (with a note) if the dry-run has not been run.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                           "experiments", "dryrun_baseline_v2")


def run(records_dir: str = "") -> List[Dict]:
    d = records_dir or DEFAULT_DIR
    files = sorted(glob.glob(os.path.join(d, "*.json")))
    if not files:
        return [{"bench": "roofline(dryrun)", "method": "missing",
                 "note": f"run `python -m repro.launch.dryrun --out {d}` first"}]
    rows = []
    for fn in files:
        with open(fn) as f:
            result = json.load(f)
        for rec in result["records"]:
            rows.append({
                "bench": "roofline(dryrun)",
                "method": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
                          f"/{rec.get('variant', '')}",
                "t_compute_ms": round(rec["t_compute_s"] * 1e3, 3),
                "t_memory_ms": round(rec["t_memory_s"] * 1e3, 3),
                "t_collective_ms": round(rec["t_collective_s"] * 1e3, 3),
                "dominant": rec["dominant"],
                "useful_flop_ratio": round(rec["useful_flop_ratio"], 4),
                "mfu_at_roofline": round(rec["mfu_at_roofline"], 4),
            })
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--records-dir", default="",
                    help=f"dry-run record directory (default {DEFAULT_DIR})")
    ap.add_argument("--out", default="BENCH_roofline.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run(records_dir=args.records_dir)
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
