"""Trace -> replay validation + the paper's what-if curves from ONE run.

Records two reduced-config runs with ``train_loop(..., trace_out=...)`` —
the paper's fixed-H schedule and the CADA-style adaptive one — then drives
``repro.trace.replay`` over the recorded spans:

  validate     the perf gate: the baseline replay (recorded knobs, no
               fabric) must land within ``TOL`` of the measured wall, and
               the replayed sync schedule must equal the
               ``TrainResult``-measured one EXACTLY, for both policies;
  sweeps       Figure-1/2-style curves re-simulated from the single
               recorded timeline under the v5e alpha-beta fabric at paper
               worker counts: comm fraction vs workers (monotone up),
               wall vs sync period H (monotone down), and wire volume per
               codec (fp32 > bf16 > int8) — no model re-run, pure replay.

The rows state the tolerance and carry ``ok`` flags; ``main`` exits
nonzero when a gate fails, so CI can run this module directly. Replayed
times are modeled (alpha-beta + roofline over measured jnp-path host
walls), not Mosaic-true device time.

  PYTHONPATH=src python -m benchmarks.bench_trace_replay \
      [--steps 40] [--out BENCH_trace.json]
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List, Tuple

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.trace import Trace
from repro.trace.chrome import export as chrome_export
from repro.trace.replay import (ReplayKnobs, replay, sweep_H, sweep_codecs,
                                sweep_workers, validate)

#: the artifact name the standalone CLI and ``benchmarks.run`` both write
#: (ISSUE 5 names this file; the module suffix would say trace_replay).
DEFAULT_OUT = "BENCH_trace.json"

#: predicted-vs-measured wall tolerance the gate enforces on traces WITHOUT
#: HLO cost meta (there the baseline replay is exact by construction; this
#: absorbs float summation order).
TOL = 0.1

#: tighter gate for traces carrying ``hlo_cost`` meta: sync overhead is
#: then priced from the compiled programs' per-region roofline ratio
#: (deterministic structure, not a noisy difference of two measured means),
#: so the prediction must hold at half the legacy tolerance.
HLO_TOL = 0.05

#: replay worker counts for the comm-fraction curve (paper Fig. 1 x-axis).
WORKERS = (1, 2, 4, 8, 16, 32)
#: replay sync periods for the speedup curve (paper Fig. 2 x-axis).
HS = (1, 2, 4, 8, 16)


def _record(policy: str, steps: int, seq: int, batch: int,
            trace_path: str) -> Tuple[object, Trace]:
    from repro.launch.train import train_loop
    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="bench", seq_len=seq, global_batch=batch,
                        kind="train")
    sync = SyncConfig(policy=policy, threshold=0.005, h_min=2, h_max=8,
                      compression="int8") if policy == "adaptive" \
        else SyncConfig(compression="int8")
    opt = OptimizerConfig.from_sync(sync, name="local_adaalter", lr=0.5,
                                    H=4, warmup_steps=10)
    res = train_loop(cfg, shape, opt, steps=steps, verbose=False,
                     trace_out=trace_path)
    return res, Trace.load(trace_path)


def _monotone(xs: List[float], up: bool, tol: float = 1e-12) -> bool:
    pairs = zip(xs, xs[1:])
    return all((b >= a - tol) if up else (b <= a + tol) for a, b in pairs)


def run(steps: int = 40, seq: int = 64, batch: int = 8,
        trace_dir: str = "benchmarks") -> List[Dict]:
    """``trace_dir`` is where the recorded traces + Chrome exports land.
    They are referenced by path from the emitted rows, so keep them next
    to the bench JSON (the CLI derives this from ``--out``) — a trace
    written to an ephemeral temp dir would leave dangling paths in the
    committed/uploaded ``BENCH_trace.json`` artifact."""
    rows = []
    traces = {}
    for policy in ("fixed_h", "adaptive"):
        path = os.path.join(trace_dir, f"trace_{policy}.json")
        res, trace = _record(policy, steps, seq, batch, path)
        traces[policy] = (path, trace)

        # ---- the perf gate: baseline replay vs the measurement ---------- #
        tol = HLO_TOL if trace.meta.get("hlo_cost") else TOL
        v = validate(trace, tol=tol)
        base = replay(trace, ReplayKnobs())
        rows.append({
            "bench": "trace_replay(validate)",
            "policy": policy, "steps": steps,
            "trace": path, "n_spans": len(trace.spans),
            "measured_warm_wall_s": round(v["measured_warm_wall_s"], 4),
            "measured_raw_wall_s": round(v["measured_span_wall_s"], 4),
            "predicted_wall_s": round(v["predicted_wall_s"], 4),
            "ratio": round(v["ratio"], 6),
            "tol": tol,
            "priced_from": v["priced_from"],
            "wall_ok": v["wall_ok"],
            "measured_sync_count": res.sync_count,
            "replayed_sync_count": base.sync_count,
            "sync_steps_exact": base.sync_steps == res.sync_steps,
            "ok": bool(v["ok"] and base.sync_count == res.sync_count
                       and base.sync_steps == res.sync_steps),
        })

    # ---- what-if sweeps from the ONE adaptive trace --------------------- #
    from repro.core import comm
    path, trace = traces["adaptive"]
    w_rows = sweep_workers(trace, WORKERS)
    fracs = [r["comm_fraction"] for r in w_rows]
    rows.append({
        "bench": "trace_replay(comm_fraction_vs_workers)",
        "trace": path, "workers": list(WORKERS),
        "comm_fraction": [round(f, 8) for f in fracs],
        "monotone_up": _monotone(fracs, up=True),
    })
    # the same curve over a 100x slower fabric — the reduced config's
    # payload is tiny, so this is where the Figure-1 shape (comm eating
    # the step) becomes visible from the very same recorded run
    slow = comm.FabricModel(**trace.meta.get("fabric", {})).scaled(0.01)
    s_rows = sweep_workers(trace, WORKERS, fabric=slow)
    s_fracs = [r["comm_fraction"] for r in s_rows]
    rows.append({
        "bench": "trace_replay(comm_fraction_vs_workers, bw/100)",
        "trace": path, "workers": list(WORKERS),
        "comm_fraction": [round(f, 8) for f in s_fracs],
        "monotone_up": _monotone(s_fracs, up=True),
    })
    # H/codec sweeps replay at the paper's 8 workers (the recorded CI run
    # may have a single worker, where there is no wire to model)
    at8 = ReplayKnobs(n_workers=8)
    h_rows = sweep_H(trace, HS, base=at8)
    walls = [r["wall_s"] for r in h_rows]
    rows.append({
        "bench": "trace_replay(wall_vs_H)",
        "trace": path, "H": list(HS),
        "wall_s": [round(w, 4) for w in walls],
        "sync_count": [r["sync_count"] for r in h_rows],
        "speedup_vs_H1": [round(r["speedup_vs_first"], 4) for r in h_rows],
        "monotone_down": _monotone(walls, up=False),
    })
    c_rows = sweep_codecs(trace, base=at8)
    wires = {r["codec"]: r["round_wire_bytes"] for r in c_rows}
    rows.append({
        "bench": "trace_replay(codec)",
        "trace": path,
        "codec": [r["codec"] for r in c_rows],
        "comm_us": [round(r["comm_s"] * 1e6, 3) for r in c_rows],
        "round_wire_mb": [round(r["round_wire_bytes"] / 1e6, 3)
                          for r in c_rows],
        "ordered": wires["fp32"] > wires["bf16"] > wires["int8"],
    })

    # ---- Chrome export of the recorded timeline (the CI artifact) ------- #
    chrome_path = path.rsplit(".json", 1)[0] + ".chrome.json"
    doc = chrome_export(path, chrome_path)
    rows.append({"bench": "trace_replay(chrome)", "trace": path,
                 "chrome": chrome_path, "n_events": len(doc["traceEvents"])})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--trace-dir", default="",
                    help="where the recorded traces + Chrome exports land; "
                         "default: next to --out, so the paths the emitted "
                         "rows reference stay stable CI artifacts")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    trace_dir = args.trace_dir or (os.path.dirname(args.out) or ".")
    rows = run(steps=args.steps, seq=args.seq, batch=args.batch,
               trace_dir=trace_dir)
    from benchmarks._cli import emit
    emit(rows, args.out)
    gates = [r for r in rows if "ok" in r or "monotone_up" in r
             or "monotone_down" in r or "ordered" in r]
    bad = [r for r in gates
           if not r.get("ok", r.get("monotone_up",
                                    r.get("monotone_down",
                                          r.get("ordered", True))))]
    if bad:
        print(f"PERF GATE FAILED: {[r['bench'] for r in bad]}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
