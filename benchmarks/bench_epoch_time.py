"""Paper Figures 1 & 2: epoch time and throughput versus number of workers.

The paper's cluster (V100s over a parameter server) is replaced by the time
model calibrated on this repo's roofline constants: one measured CPU step
provides the *compute* term shape; communication is the analytic ring
all-reduce over the v5e fabric (ICI within a pod, DCN across pods), with the
per-algorithm amortization the paper derives (1, 1/H, 2/H).

The reproduced claims: comm grows with workers for synchronous AdaGrad/
AdaAlter; Local AdaAlter's curve stays near the "no-communication" lower
bound; larger H approaches it.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import get_arch
from repro.core.comm import FabricModel, step_time
from repro.models.counting import count_params

# Paper's epoch: 20_000 steps x 8 workers x 256 batch.
STEPS_PER_EPOCH = 20_000
BATCH_PER_WORKER = 256
COMPUTE_S = 0.55                      # nominal per-step compute (paper ~0.5s)

ALGOS = [("adagrad", 1), ("adaalter", 1), ("local_adaalter", 4),
         ("local_adaalter", 8), ("local_adaalter", 16), ("none", 1)]


def run(workers_list=(1, 2, 4, 8, 16, 32), cross_pod_at: int = 16) -> List[Dict]:
    n_params = count_params(get_arch("biglstm"))
    fabric = FabricModel()
    rows = []
    for n in workers_list:
        for name, H in ALGOS:
            t = step_time(name, n_params, COMPUTE_S, n, H, fabric,
                          cross_pod=n >= cross_pod_at)
            label = (f"{name}-H{H}" if name.startswith("local")
                     else ("ideal-compute-only" if name == "none" else name))
            rows.append({
                "bench": "epoch_time(fig1)+throughput(fig2)",
                "method": label,
                "workers": n,
                "step_s": round(t, 4),
                "epoch_hours": round(t * STEPS_PER_EPOCH / 3600, 3),
                "throughput_samples_s": round(n * BATCH_PER_WORKER / t, 1),
            })
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_epoch_time.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run()
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
