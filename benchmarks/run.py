"""Benchmark harness — one module per paper table/figure.

  python -m benchmarks.run [--only convergence,kernels,...] [--csv out.csv]

  bench_epoch_time   Fig. 1 (epoch time vs workers) + Fig. 2 (throughput)
  bench_convergence  Fig. 3 + Table 2 (PPL per algorithm at equal epochs)
  bench_kernels      fused AdaAlter update vs unfused lowering
  bench_sync_compression  int8+error-feedback sync vs fp32 payload
  bench_adaptive_sync     CADA-style adaptive sync policy vs fixed H=4
  bench_flat_step    flat parameter plane vs per-leaf hot path
  bench_trace_replay trace-driven what-if replay vs measured walls
  bench_roofline     §Roofline table from the dry-run artifacts

Every module is also runnable standalone with a uniform ``--out`` JSON path
defaulting to ``BENCH_<name>.json`` at the repo root; this harness writes
the same per-bench files (plus the merged CSV), so one ``benchmarks.run``
invocation refreshes the whole ``BENCH_*.json`` trajectory.
"""
from __future__ import annotations

import argparse
import csv
import io
import json
import os
import sys
import time

ALL = ["epoch_time", "convergence", "kernels", "sync_compression",
       "adaptive_sync", "flat_step", "trace_replay", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {ALL}")
    ap.add_argument("--csv", default="", help="also write rows to this CSV")
    ap.add_argument("--json-dir", default=".",
                    help="write per-bench rows as BENCH_<name>.json here "
                         "('' disables)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller step counts (CI mode)")
    args = ap.parse_args()
    which = [w for w in (args.only.split(",") if args.only else ALL) if w]

    rows = []
    for name in which:
        t0 = time.time()
        print(f"== bench_{name}", flush=True)
        if name == "epoch_time":
            from benchmarks.bench_epoch_time import run as r
            new = r()
        elif name == "convergence":
            from benchmarks.bench_convergence import run as r
            new = r(steps=30 if args.quick else 120)
        elif name == "kernels":
            from benchmarks.bench_kernels import run as r
            new = r(n=(1 << 18) if args.quick else (1 << 22))
        elif name == "sync_compression":
            from benchmarks.bench_sync_compression import run as r
            new = r(steps=60 if args.quick else 200,
                    n=(1 << 18) if args.quick else (1 << 22))
        elif name == "adaptive_sync":
            from benchmarks.bench_adaptive_sync import run as r
            new = r(steps=60 if args.quick else 120)
        elif name == "flat_step":
            from benchmarks.bench_flat_step import run as r
            new = r(steps=12 if args.quick else 30)
        elif name == "trace_replay":
            from benchmarks.bench_trace_replay import run as r
            # traces land next to the BENCH json so the paths its rows
            # reference survive as artifacts
            new = r(steps=24 if args.quick else 40,
                    trace_dir=args.json_dir or ".")
        elif name == "roofline":
            from benchmarks.bench_roofline import run as r
            new = r()
        else:
            print(f"   unknown bench {name!r}", file=sys.stderr)
            continue
        rows += new
        if args.json_dir:
            # the artifact name is the module's contract (DEFAULT_OUT where
            # it differs from the BENCH_<name>.json convention), so the
            # harness can never drift from the standalone CLI
            import importlib
            mod = importlib.import_module(f"benchmarks.bench_{name}")
            os.makedirs(args.json_dir, exist_ok=True)
            out = os.path.join(args.json_dir,
                               getattr(mod, "DEFAULT_OUT",
                                       f"BENCH_{name}.json"))
            with open(out, "w") as f:
                json.dump(new, f, indent=1)
        print(f"   done in {time.time() - t0:.1f}s ({len(rows)} rows total)",
              flush=True)

    # union of keys, stable order
    keys = []
    for row in rows:
        for k in row:
            if k not in keys:
                keys.append(k)
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=keys)
    w.writeheader()
    w.writerows(rows)
    print(buf.getvalue())
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(buf.getvalue())
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
