"""Benchmark harness — one module per paper table/figure.

  python -m benchmarks.run [--only convergence,kernels,...] [--csv out.csv]

  bench_epoch_time   Fig. 1 (epoch time vs workers) + Fig. 2 (throughput)
  bench_convergence  Fig. 3 + Table 2 (PPL per algorithm at equal epochs)
  bench_kernels      fused AdaAlter update vs unfused lowering
  bench_sync_compression  int8+error-feedback sync vs fp32 payload
  bench_adaptive_sync     CADA-style adaptive sync policy vs fixed H=4
  bench_flat_step    flat parameter plane vs per-leaf hot path
  bench_roofline     §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import csv
import io
import sys
import time

ALL = ["epoch_time", "convergence", "kernels", "sync_compression",
       "adaptive_sync", "flat_step", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of {ALL}")
    ap.add_argument("--csv", default="", help="also write rows to this CSV")
    ap.add_argument("--quick", action="store_true",
                    help="smaller step counts (CI mode)")
    args = ap.parse_args()
    which = [w for w in (args.only.split(",") if args.only else ALL) if w]

    rows = []
    for name in which:
        t0 = time.time()
        print(f"== bench_{name}", flush=True)
        if name == "epoch_time":
            from benchmarks.bench_epoch_time import run as r
            rows += r()
        elif name == "convergence":
            from benchmarks.bench_convergence import run as r
            rows += r(steps=30 if args.quick else 120)
        elif name == "kernels":
            from benchmarks.bench_kernels import run as r
            rows += r(n=(1 << 18) if args.quick else (1 << 22))
        elif name == "sync_compression":
            from benchmarks.bench_sync_compression import run as r
            rows += r(steps=60 if args.quick else 200,
                      n=(1 << 18) if args.quick else (1 << 22))
        elif name == "adaptive_sync":
            from benchmarks.bench_adaptive_sync import run as r
            rows += r(steps=60 if args.quick else 120)
        elif name == "flat_step":
            from benchmarks.bench_flat_step import run as r
            rows += r(steps=12 if args.quick else 30)
        elif name == "roofline":
            from benchmarks.bench_roofline import run as r
            rows += r()
        else:
            print(f"   unknown bench {name!r}", file=sys.stderr)
            continue
        print(f"   done in {time.time() - t0:.1f}s ({len(rows)} rows total)",
              flush=True)

    # union of keys, stable order
    keys = []
    for row in rows:
        for k in row:
            if k not in keys:
                keys.append(k)
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=keys)
    w.writeheader()
    w.writerows(rows)
    print(buf.getvalue())
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(buf.getvalue())
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
