"""Paper Figure 3 + Table 2: convergence of each algorithm at equal epochs.

Trains the paper's Big LSTM family (reduced for CPU) on the synthetic
non-IID stream with AdaGrad / AdaAlter / Local AdaAlter H in {4,8,12,16},
reporting final loss+PPL and the simulated wall time from the comm model.
The reproduced claims are *relative*: AdaAlter≈AdaGrad; H up => time down,
PPL slightly up.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.comm import FabricModel, step_time
from repro.launch.train import train_loop
from repro.models.counting import count_params

RUNS = [("adagrad", 1), ("adaalter", 1), ("local_adaalter", 4),
        ("local_adaalter", 8), ("local_adaalter", 12), ("local_adaalter", 16)]


def run(steps: int = 120, seq: int = 64, batch: int = 8,
        workers: int = 8) -> List[Dict]:
    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="bench", seq_len=seq, global_batch=batch,
                        kind="train")
    n_params_full = count_params(get_arch("biglstm"))    # comm at paper scale
    fabric = FabricModel()
    compute_s = 0.1                                       # nominal GPU step
    rows = []
    for name, H in RUNS:
        opt = OptimizerConfig(name=name, lr=0.5, H=H, warmup_steps=40)
        res = train_loop(cfg, shape, opt, steps=steps, verbose=False)
        t = step_time(name, n_params_full, compute_s, workers, H, fabric)
        rows.append({
            "bench": "convergence(fig3/table2)",
            "method": f"{name}-H{H}" if name.startswith("local") else name,
            "final_loss": round(res.final_loss, 4),
            "final_ppl": round(min(res.ppl[-1], 1e9), 2),
            "sim_step_ms": round(t * 1e3, 3),
            "steps": steps,
        })
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--out", default="BENCH_convergence.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run(steps=args.steps)
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
