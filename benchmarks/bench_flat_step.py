"""Flat parameter plane vs per-leaf hot path: launches, padding, collectives.

Four measurements for ``OptimizerConfig.flat`` (core/flatspace.py) on the
paper's Big LSTM config:

  launches     Pallas kernel launches per compiled step, counted directly
               in the traced jaxpr: the per-leaf path pays one
               ``pallas_call`` per parameter leaf for the AdaAlter update
               (plus one per payload leaf for the fused EF sync encode on
               sync steps); the flat plane pays ONE of each — the L -> 1
               claim of the ISSUE, measured, not asserted;
  padding      pad-to-tile elements: the per-leaf path re-pads every leaf
               to the kernel tile on EVERY launch, the plane pays its slot
               padding once at pack time;
  collectives  sync-round collectives (per-leaf: one small all-reduce per
               payload leaf; flat: ONE flat wire array) and the alpha-beta
               ``comm.collective_time`` launch/latency model at paper scale;
  wall         measured wall time per train step of the jnp fallback path
               (use_pallas=False — interpret-mode Pallas timing tracks
               emulation overhead, not dispatch cost) for both layouts on
               the reduced config, plus their final losses (the two paths
               are bitwise identical in state; tests/test_flat_step.py).

  sharded      the same flat step on a 4-device (2 workers x 2-way shard)
               CPU mesh (subprocess — the forced host-device count must
               not perturb the single-device sections): kernel launches
               sharded vs replicated, and per-device plane bytes, which
               ~halve under 2-way sharding.

  PYTHONPATH=src python -m benchmarks.bench_flat_step \
      [--steps 20] [--out BENCH_flat_step.json]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.core import comm
from repro.core.flatspace import FlatSpace
from repro.data import SyntheticLM, make_train_batch
from repro.kernels.quantize import TILE_BLOCKS
from repro.kernels.tiling import padded_size
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs
from repro.launch.train import make_cpu_mesh
from repro.models.counting import count_params


def count_pallas_calls(jaxpr) -> int:
    """Recursively count ``pallas_call`` eqns in a (closed) jaxpr."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                n += count_pallas_calls(v)
    return n


def _mk_opt(flat: bool, use_pallas: bool) -> OptimizerConfig:
    return OptimizerConfig.from_sync(
        SyncConfig(compression="int8", fused=True),
        name="local_adaalter", lr=0.5, H=4, warmup_steps=10,
        use_pallas=use_pallas, flat=flat)


def run(steps: int = 20, seq: int = 64, batch: int = 8) -> List[Dict]:
    rows = []
    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="bench", seq_len=seq, global_batch=batch,
                        kind="train")
    mesh = make_cpu_mesh()
    with mesh:
        plan = resolve_plan(cfg, mesh, optimizer="local_adaalter")

        # ---- kernel launches per compiled step (traced, not modeled) ---- #
        launches = {}
        programs = {}
        for mode, flat in (("per_leaf", False), ("flat", True)):
            p = build_train_programs(cfg, shape, _mk_opt(flat, True), mesh,
                                     plan)
            programs[mode] = p
            state_abs = jax.eval_shape(p.init_fn, jax.random.PRNGKey(0))
            from repro.launch.steps import train_batch_specs
            batch_abs = train_batch_specs(cfg, shape, p.n_workers)
            launches[mode] = {
                v: count_pallas_calls(jax.make_jaxpr(
                    lambda a, b, c, fn=fn: fn(a, b, c))(
                        *state_abs, batch_abs))
                for v, fn in (("local_step", p.local_step),
                              ("sync_step", p.sync_step))}
        fs: FlatSpace = programs["flat"].flatspace
        rows.append({
            "bench": "flat_step(launches)",
            "n_param_leaves": fs.n_leaves,
            "per_leaf": launches["per_leaf"],
            "flat": launches["flat"],
            "local_step_shrink": (launches["per_leaf"]["local_step"]
                                  / max(launches["flat"]["local_step"], 1)),
        })

        # ---- padded elements: per launch (per-leaf) vs once (flat) ------ #
        upd_pad_per_step = sum(s.padded - s.size for s in fs.slots)
        sync_block = 256
        # per-leaf fused EF: each payload leaf padded to the quantization
        # block, then its row count to the kernel tile — every sync round
        per_leaf_sync_pad = sum(
            padded_size(padded_size(s.size, sync_block) // sync_block,
                        TILE_BLOCKS) * sync_block - s.size
            for s in fs.slots) * 2                       # params + B²
        flat_sync_pad = (padded_size(2 * fs.plane_size // sync_block,
                                     TILE_BLOCKS) * sync_block
                         - 2 * fs.n_real)
        rows.append({
            "bench": "flat_step(padding)",
            "real_elems": fs.n_real,
            "per_leaf_update_pad_elems_per_step": upd_pad_per_step,
            "flat_plane_pad_elems_once": fs.pad_elems,
            "per_leaf_sync_pad_elems_per_round": per_leaf_sync_pad,
            "flat_sync_pad_elems_per_round": flat_sync_pad,
            "note": "per-leaf pays its pads on EVERY launch; the plane "
                    "pays slot padding once at pack time",
        })

        # ---- collectives per sync round + alpha-beta time at paper scale - #
        n_params = count_params(get_arch("biglstm"))
        round_bytes = comm.sync_payload_bytes("local_adaalter", n_params,
                                              compression="int8")
        n_coll = int(fs.n_leaves
                     * comm.sync_round_multiplier("local_adaalter"))
        workers = 8                                     # paper's cluster
        t_leaf = comm.collective_time(round_bytes, n_coll, workers)
        t_flat = comm.collective_time(round_bytes, 1, workers)
        rows.append({
            "bench": "flat_step(collectives)",
            "collectives_per_round_per_leaf": n_coll,
            "collectives_per_round_flat": 1,
            "round_mb": round(round_bytes / 1e6, 2),
            "alpha_beta_per_leaf_ms": round(t_leaf * 1e3, 4),
            "alpha_beta_flat_ms": round(t_flat * 1e3, 4),
            "latency_overhead_shrink": round(t_leaf / t_flat, 2),
        })

        # ---- measured wall time, jnp fallback path ---------------------- #
        walls = {}
        finals = {}
        for mode, flat in (("per_leaf", False), ("flat", True)):
            p = build_train_programs(cfg, shape, _mk_opt(flat, False), mesh,
                                     plan)
            R = p.n_workers
            ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                             n_workers=R, seed=0, non_iid=True)
            params, state = p.init_fn(jax.random.PRNGKey(0))
            batches = [jax.tree_util.tree_map(
                jnp.asarray, make_train_batch(cfg, shape, ds, s,
                                              n_workers=R))
                for s in range(steps)]
            loss = None
            for s in range(2):                          # warmup/compile
                fn = p.sync_step if (s + 1) % 4 == 0 else p.local_step
                params, state, m = fn(params, state, batches[s])
            jax.block_until_ready(params)
            t0 = time.perf_counter()
            for s in range(2, steps):
                fn = p.sync_step if (s + 1) % 4 == 0 else p.local_step
                params, state, m = fn(params, state, batches[s])
                loss = m["loss"]
            jax.block_until_ready(params)
            walls[mode] = (time.perf_counter() - t0) / max(steps - 2, 1)
            finals[mode] = float(loss)
            rows.append({
                "bench": "flat_step(wall)",
                "mode": mode, "steps": steps - 2,
                "ms_per_step": round(walls[mode] * 1e3, 2),
                "final_loss": round(finals[mode], 5),
            })
        rows[-1]["speedup_vs_per_leaf"] = round(
            walls["per_leaf"] / walls["flat"], 3)
    rows.extend(run_sharded())
    return rows


_SHARDED_SCRIPT = r"""
import dataclasses, json
import jax
from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.configs.base import SyncConfig
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs, train_batch_specs
from benchmarks.bench_flat_step import count_pallas_calls, _mk_opt

cfg = reduced(get_arch("biglstm"), vocab=512)
shape = ShapeConfig(name="bench", seq_len=64, global_batch=8, kind="train")
mesh = jax.make_mesh((2, 2), ("data", "model"))
out = {}
with mesh:
    plan = resolve_plan(cfg, mesh, optimizer="local_adaalter")
    for mode, pl in (("sharded", plan),
                     ("replicated", dataclasses.replace(plan, tp_axis=""))):
        p = build_train_programs(cfg, shape, _mk_opt(True, True), mesh, pl)
        state_abs = jax.eval_shape(p.init_fn, jax.random.PRNGKey(0))
        batch_abs = train_batch_specs(cfg, shape, p.n_workers)
        fs = p.flatspace
        plane, _ = p.init_fn(jax.random.PRNGKey(0))
        shard = plane.sharding.shard_shape(plane.shape)
        out[mode] = {
            "n_shards": p.n_shards,
            "launches": {v: count_pallas_calls(jax.make_jaxpr(
                lambda a, b, c, fn=fn: fn(a, b, c))(*state_abs, batch_abs))
                for v, fn in (("local_step", p.local_step),
                              ("sync_step", p.sync_step))},
            "plane_size": fs.plane_size,
            "per_device_plane_bytes": 4 * shard[0] * shard[1],
        }
print("BENCH-SHARDED " + json.dumps(out))
"""


def run_sharded() -> List[Dict]:
    """Sharded-flat vs replicated-flat on a (2 workers x 2-way) mesh.

    Runs in a subprocess: the XLA host-device count must be forced to 4
    BEFORE the backend initialises, and doing so here would perturb the
    single-device numbers of the sections above."""
    import json
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": os.pathsep.join(
               [repo, os.path.join(repo, "src")])}
    try:
        proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                              env=env, capture_output=True, text=True,
                              timeout=900)
        line = next(l for l in proc.stdout.splitlines()
                    if l.startswith("BENCH-SHARDED "))
        data = json.loads(line[len("BENCH-SHARDED "):])
    except Exception as e:                       # keep the bench usable
        return [{"bench": "flat_step(sharded)",
                 "note": f"4-device subprocess failed: {e!r}"}]
    sh, re_ = data["sharded"], data["replicated"]
    return [{
        "bench": "flat_step(sharded)",
        "mesh": "2 workers x 2 shards",
        "n_shards": sh["n_shards"],
        "launches_sharded": sh["launches"],
        "launches_replicated": re_["launches"],
        "per_device_plane_bytes_sharded": sh["per_device_plane_bytes"],
        "per_device_plane_bytes_replicated": re_["per_device_plane_bytes"],
        "per_device_bytes_shrink": round(
            re_["per_device_plane_bytes"] / sh["per_device_plane_bytes"], 3),
        "note": "per-device bytes ~halve under 2-way sharding (tail pad "
                "rounds the plane to shards*ALIGN, so not exactly 2x)",
    }]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=20,
                    help="wall-time section train steps")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default="BENCH_flat_step.json",
                    help="write rows as JSON here ('' skips)")
    args = ap.parse_args()
    rows = run(steps=args.steps, seq=args.seq, batch=args.batch)
    from benchmarks._cli import emit
    emit(rows, args.out)


if __name__ == "__main__":
    main()
