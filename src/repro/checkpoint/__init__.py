"""Sharding-aware checkpointing (npz payload + JSON pytree manifest)."""
from repro.checkpoint.store import (checkpoint_keys, checkpoint_layout,
                                    disk_like, latest_step,
                                    restore_checkpoint, save_checkpoint)

__all__ = ["checkpoint_keys", "checkpoint_layout", "disk_like",
           "latest_step", "restore_checkpoint", "save_checkpoint"]
