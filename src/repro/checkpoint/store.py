"""Checkpoint store: flat-key npz payload + JSON manifest.

Design goals (matched to this framework, not a general orbax clone):

* works for arbitrary pytrees (params with a leading worker axis, optimizer
  states with scalar counters and accumulator subtrees);
* *sharding-aware restore*: arrays are restored with ``jax.device_put`` onto
  the sharding pytree of the live train state, so a checkpoint written on one
  mesh layout restores onto another (the npz holds the fully-replicated
  logical array — fine at the model scales we train on CPU; the full-scale
  dry-run configs never allocate, hence never checkpoint);
* atomic: written to ``step_<n>.tmp`` then renamed, so a crash mid-write
  never corrupts ``latest``.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "/"
_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _SEP.join(_path_part(p) for p in path)
        flat[key] = leaf
    return flat, treedef


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):        # GetAttrKey: registered dataclasses
        return str(p.name)        # (e.g. core.sync_engine.SyncState)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    """state: any pytree of jax/np arrays. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    flat, treedef = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # np.savez silently degrades ml_dtypes (bfloat16 etc.) to void — store a
    # same-width unsigned view and record the true dtype in the manifest.
    true_dtypes = {k: v.dtype.name for k, v in arrays.items()}
    arrays = {
        k: v.view(f"uint{8 * v.dtype.itemsize}") if v.dtype.kind == "V" or
        v.dtype.name not in np.sctypeDict else v
        for k, v in arrays.items()
    }
    path = os.path.join(directory, f"step_{step}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(arrays),
        "dtypes": true_dtypes,
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.isdir(path):
        # overwrite an existing checkpoint for this step
        import shutil
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def checkpoint_keys(directory: str, *, step: Optional[int] = None
                    ) -> Tuple[str, ...]:
    """Flat leaf keys of a saved checkpoint (from its manifest), without
    loading the arrays — lets callers pick a restore template matching the
    on-disk structure (e.g. checkpoints predating a new state leaf) instead
    of probing with mismatching restores."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory!r}")
    path = os.path.join(directory, f"step_{step}", "manifest.json")
    with open(path) as f:
        return tuple(json.load(f)["keys"])


def checkpoint_layout(directory: str, *, step: Optional[int] = None) -> str:
    """Which parameter layout a checkpoint holds: ``'flat'`` (packed
    FlatSpace planes — params are ONE array, bare ``#0`` key) or
    ``'per_leaf'`` (the legacy pytree layout, ``#0/...`` subtree keys).

    Restores work across the two (``core/flatspace.py`` adapters convert
    after the restore); this is how ``train_loop`` picks the matching
    restore template without probing."""
    from repro.core.flatspace import is_flat_checkpoint
    return ("flat" if is_flat_checkpoint(checkpoint_keys(directory,
                                                         step=step))
            else "per_leaf")


def disk_like(directory: str, like: Any, *, step: Optional[int] = None) -> Any:
    """``like`` with every leaf's SHAPE replaced by the on-disk manifest
    shape (dtype kept) — the restore template for cross-mesh flat-plane
    restores, where a checkpoint written under one (workers × shards) mesh
    carries different plane/counter shapes than the live run
    (``core.flatspace.adapt_flat_state`` reshards after the restore).
    Keys must match exactly; only shapes may differ."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory!r}")
    path = os.path.join(directory, f"step_{step}", "manifest.json")
    with open(path) as f:
        shapes = json.load(f)["shapes"]
    flat_like, treedef = _flatten(like)
    missing = set(flat_like) - set(shapes)
    if missing:
        raise ValueError(f"checkpoint/state mismatch: missing="
                         f"{sorted(missing)[:5]}")
    leaves = [jax.ShapeDtypeStruct(tuple(shapes[k]), flat_like[k].dtype)
              for k in flat_like]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(directory: str, like: Any, *, step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a live pytree or eval_shape).

    ``shardings``: optional pytree of NamedShardings parallel to ``like``;
    restored arrays are device_put with them (sharded load).
    Returns (state, step). Raises FileNotFoundError if no checkpoint exists.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory!r}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    for k, name in manifest["dtypes"].items():
        if arrays[k].dtype.name != name:      # stored as a width-matched view
            import ml_dtypes  # noqa: F401  (registers bfloat16 & friends)
            arrays[k] = arrays[k].view(np.dtype(name))

    flat_like, treedef = _flatten(like)
    missing = set(flat_like) - set(arrays)
    extra = set(arrays) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint/state mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    if shardings is not None:
        flat_sh, _ = _flatten(shardings)
    leaves = []
    for key in flat_like:
        arr = arrays[key]
        want = flat_like[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {want.shape}")
        if arr.dtype != want.dtype:
            arr = arr.astype(want.dtype)
        if shardings is not None:
            arr = jax.device_put(arr, flat_sh[key])
        leaves.append(arr)
    # rebuild in treedef order: tree_flatten_with_path and tree_unflatten agree
    keys_in_order = list(flat_like)
    state = jax.tree_util.tree_unflatten(
        treedef, [dict(zip(keys_in_order, leaves))[k] for k in keys_in_order])
    return state, step
