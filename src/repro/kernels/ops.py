"""Jit'd public wrappers around the Pallas kernels.

``tree_fused_update`` applies the fused (Local) AdaAlter update across a
whole parameter pytree. On CPU (this container) the kernels run in
``interpret=True`` mode; on TPU the same code path compiles the Mosaic
kernel. ``use_pallas=False`` falls back to the pure-jnp oracle, which is
what the unfused production path uses anyway — the two are allclose-tested
against each other in tests/test_kernels.py.
"""
from __future__ import annotations

import jax

from repro.kernels.adaalter_update import fused_update
from repro.kernels.ref import fused_update_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def leaf_fused_update(x, g, b2_sync, b2_local, eta, extra, *,
                      use_pallas: bool = True):
    if not use_pallas:
        return fused_update_ref(x, g, b2_sync, b2_local, eta, extra)
    return fused_update(x, g, b2_sync, b2_local, eta, extra,
                        interpret=not on_tpu())


def tree_fused_update(params, grads, b2_sync, b2_local, eta, extra, *,
                      use_pallas: bool = True):
    """Apply the fused update leaf-wise. Returns (new_params, new_b2_local)."""
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_bs = treedef.flatten_up_to(b2_sync)
    flat_bl = treedef.flatten_up_to(b2_local)
    ys, bls = [], []
    for p, g, bs, bl in zip(flat_p, flat_g, flat_bs, flat_bl):
        y, nbl = leaf_fused_update(p, g, bs, bl, eta, extra,
                                   use_pallas=use_pallas)
        ys.append(y)
        bls.append(nbl)
    return treedef.unflatten(ys), treedef.unflatten(bls)
