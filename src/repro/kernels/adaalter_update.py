"""Pallas TPU kernel: fused (Local) AdaAlter parameter update.

One pass over HBM per optimizer step: reads (x, g, b2_sync, b2_local),
writes (y, new_b2_local) — the paper's line-6/7 pair

    y           = x − η · g / sqrt(b2_sync + t'·ε²·1)
    b2_local    = b2_local + g∘g

fused into a single VMEM-tiled elementwise kernel. The optimizer update is
the hot loop the paper's wall-time tables hinge on (it runs once per local
step over EVERY parameter), and the fusion eliminates the intermediate
normalized-gradient and denominator round-trips to HBM: 4 reads + 2 writes
instead of the 7 reads + 3 writes of the unfused lowering.

Layout: arbitrary parameter leaves are flattened, padded to a multiple of
(BLOCK_ROWS*128) and viewed as (rows, 128) — the native VPU lane width —
with a 1-D grid over row blocks. Scalars (η, t'·ε²) ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import pad_rows, to_blocks

LANES = 128
BLOCK_ROWS = 512          # (512, 128) fp32 tile = 256 KiB/operand in VMEM


def _kernel(scalars_ref, x_ref, g_ref, bs_ref, bl_ref, y_ref, blo_ref):
    eta = scalars_ref[0]
    extra = scalars_ref[1]                       # t' * eps^2   (AdaAlter: eps^2)
    g = g_ref[...].astype(jnp.float32)
    denom = jax.lax.rsqrt(bs_ref[...] + extra)
    x = x_ref[...].astype(jnp.float32)
    y_ref[...] = (x - eta * g * denom).astype(y_ref.dtype)
    blo_ref[...] = bl_ref[...] + g * g


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_update_2d(x, g, b2_sync, b2_local, eta, extra, *,
                    block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """Core pallas_call on a (rows, 128) view. b2_* must be float32."""
    rows = x.shape[0]
    assert x.shape[1] == LANES and rows % block_rows == 0, x.shape
    scalars = jnp.stack([jnp.asarray(eta, jnp.float32),
                         jnp.asarray(extra, jnp.float32)])
    grid = (rows // block_rows,)
    tile = (block_rows, LANES)
    bspec = pl.BlockSpec(tile, lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            bspec, bspec, bspec, bspec,
        ],
        out_specs=[bspec, bspec],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(b2_local.shape, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, x, g, b2_sync, b2_local)


def _to_2d(a, block_rows):
    return pad_rows(to_blocks(a, LANES, 0), block_rows)


def fused_update(x, g, b2_sync, b2_local, eta, extra, *,
                 block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """Fused update on an arbitrarily-shaped leaf. Returns (y, new_b2_local)."""
    shape, size = x.shape, x.size
    x2 = _to_2d(x, block_rows)
    g2 = _to_2d(g, block_rows)
    bs2 = _to_2d(b2_sync.astype(jnp.float32), block_rows)
    bl2 = _to_2d(b2_local.astype(jnp.float32), block_rows)
    y2, blo2 = fused_update_2d(x2, g2, bs2, bl2, eta, extra,
                               block_rows=block_rows, interpret=interpret)
    y = y2.reshape(-1)[:size].reshape(shape)
    blo = blo2.reshape(-1)[:size].reshape(shape)
    return y, blo


# --------------------------------------------------------------------------- #
# flat-plane variant: ONE pallas_call for the whole parameter plane
# --------------------------------------------------------------------------- #
def _flat_kernel(scalars_ref, x_ref, g_ref, bs_ref, bl_ref, rnd_ref,
                 y_ref, blo_ref):
    """Same math as :func:`_kernel` on fp32 planes; the ``rnd`` sidecar
    (one fp32 flag per row) marks rows whose leaf dtype is bfloat16 — those
    writes round through bf16 so the plane keeps holding exactly the bits
    the per-leaf bf16 store would have produced."""
    eta = scalars_ref[0]
    extra = scalars_ref[1]
    g = g_ref[...]
    denom = jax.lax.rsqrt(bs_ref[...] + extra)
    y = x_ref[...] - eta * g * denom
    y16 = y.astype(jnp.bfloat16).astype(jnp.float32)
    y_ref[...] = jnp.where(rnd_ref[...] > 0, y16, y)
    blo_ref[...] = bl_ref[...] + g * g


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def flat_fused_update(plane, g_plane, bs_plane, bl_plane, eta, extra,
                      rnd_rows, *, block_rows: int = BLOCK_ROWS,
                      interpret: bool = False):
    """One-launch Local AdaAlter step over whole fp32 planes.

    Planes are ``(..., P)`` with ``P`` a multiple of ``block_rows*128``
    (the FlatSpace slot alignment — padding was paid once at pack time, so
    unlike :func:`fused_update` there is NO per-call pad here). ``rnd_rows``
    is the per-row (rows, 1) fp32 bf16-rounding sidecar covering either the
    full ``(..., P)`` row space or ONE plane row (``P // 128`` rows) — the
    latter is what the shard-local call under ``shard_map`` passes: a
    per-shard sidecar view, tiled across the leading (worker) axes here.
    Returns (new_plane, new_b2_local_plane).
    """
    shape = plane.shape
    x2 = plane.reshape(-1, LANES)
    rows = x2.shape[0]
    assert rows % block_rows == 0, (shape,)
    if rnd_rows.shape[0] != rows:
        assert rows % rnd_rows.shape[0] == 0, (shape, rnd_rows.shape)
        rnd_rows = jnp.tile(rnd_rows, (rows // rnd_rows.shape[0], 1))
    assert rnd_rows.shape == (rows, 1), (shape, rnd_rows.shape)
    scalars = jnp.stack([jnp.asarray(eta, jnp.float32),
                         jnp.asarray(extra, jnp.float32)])
    grid = (rows // block_rows,)
    bspec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    rspec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    y2, blo2 = pl.pallas_call(
        _flat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            bspec, bspec, bspec, bspec, rspec,
        ],
        out_specs=[bspec, bspec],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
            jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, x2, g_plane.reshape(-1, LANES), bs_plane.reshape(-1, LANES),
      bl_plane.reshape(-1, LANES), rnd_rows)
    return y2.reshape(shape), blo2.reshape(shape)
