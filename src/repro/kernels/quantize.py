"""Pallas TPU kernel pair: per-block int8 quantize / dequantize.

The Local AdaAlter sync all-reduce moves ``2P/H`` fp32 per step (params +
accumulators — the paper's headline claim). This kernel pair compresses that
payload to int8 with one fp32 scale per 256-element block, shrinking the
modeled sync volume ~4x (1 byte/value + 4/256 bytes of scale vs 4 bytes),
at a quantization error the error-feedback residuals in
``core.optimizers.compressed_sync`` fold back into the next round.

Layout mirrors ``adaalter_update.py``: payloads are flattened, padded to a
multiple of BLOCK (=256 = 2 VPU lane rows) and viewed as ``(nblocks, BLOCK)``
— one quantization block per row — with a 1-D grid over row tiles. Scales
are emitted as an ``(nblocks, 1)`` fp32 sidecar. On CPU (this container) the
kernels run in ``interpret=True`` mode; on TPU the same code compiles to
Mosaic (TILE_BLOCKS=512 keeps the int8 store tile a multiple of the (32,128)
int8 tiling). Validated against the jnp oracles in ``kernels/ref.py``
(tests/test_quantize.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import from_blocks, pad_rows, to_blocks

BLOCK = 256               # elements per quantization block (2 x 128 lanes)
TILE_BLOCKS = 512         # blocks per grid step: (512, 256) f32 = 512 KiB VMEM

# back-compat aliases: the padding/blocked-view layout now lives in
# kernels/tiling.py (shared with sync_fused.py and the flat-plane packer)
_pad_rows = pad_rows
_to_blocks = to_blocks
_from_blocks = from_blocks


def block_quantize(v):
    """THE symmetric per-block int8 quantization: rowwise scale = max|v|/127,
    q = round(v/scale) ∈ [−127, 127] (all-zero rows quantize to 0).

    Plain jnp ops on a (rows, block) fp32 view, usable inside Pallas kernel
    bodies and oracles alike — the single definition every path shares
    (``_quant_kernel`` here, both fused EF kernels in ``sync_fused.py``,
    and the ``kernels/ref.py`` oracle), because the bitwise contract
    between the per-leaf and flat paths hinges on the math staying
    expression-for-expression identical. Returns ``(q int8, scale fp32
    (rows, 1))``.
    """
    scale = jnp.max(jnp.abs(v), axis=1, keepdims=True) / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(v * inv), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def _quant_kernel(x_ref, q_ref, s_ref):
    q, scale = block_quantize(x_ref[...].astype(jnp.float32))
    q_ref[...] = q
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, y_ref):
    y_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_blocks", "interpret"))
def quantize_blocks(x2d, *, tile_blocks: int = TILE_BLOCKS,
                    interpret: bool = False):
    """Quantize a (nblocks, block) view. Returns (q int8, scales fp32 (nb,1))."""
    nb, block = x2d.shape
    xp = _pad_rows(x2d, tile_blocks)
    grid = (xp.shape[0] // tile_blocks,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_blocks, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_blocks, block), lambda i: (i, 0)),
                   pl.BlockSpec((tile_blocks, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, jnp.int8),
                   jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(xp)
    return q[:nb], s[:nb]


@functools.partial(jax.jit, static_argnames=("tile_blocks", "interpret"))
def dequantize_blocks(q2d, scales, *, tile_blocks: int = TILE_BLOCKS,
                      interpret: bool = False):
    """Dequantize back to fp32: x̂ = q · scale, rowwise."""
    nb, block = q2d.shape
    qp = _pad_rows(q2d, tile_blocks)
    sp = _pad_rows(scales, tile_blocks)
    grid = (qp.shape[0] // tile_blocks,)
    y = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_blocks, block), lambda i: (i, 0)),
                  pl.BlockSpec((tile_blocks, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_blocks, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=interpret,
    )(qp, sp)
    return y[:nb]


# --------------------------------------------------------------------------- #
# arbitrary-leaf wrappers
# --------------------------------------------------------------------------- #
def quantize(x, *, block: int = BLOCK, batch_ndim: int = 0,
             use_pallas: bool = True, interpret: bool | None = None):
    """Per-block int8 quantization of an arbitrarily-shaped array.

    Returns ``(q, scales)`` where ``q`` is int8 of shape (nblocks, block)
    and ``scales`` fp32 (nblocks, 1). Axis layout (and hence exact values)
    depends on ``batch_ndim``; round-trip with :func:`dequantize` using the
    same arguments.
    """
    from repro.kernels.ref import quantize_blocks_ref
    x2d = _to_blocks(x, block, batch_ndim)
    if not use_pallas:
        return quantize_blocks_ref(x2d)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return quantize_blocks(x2d, interpret=interpret)


def dequantize(q, scales, shape, *, block: int = BLOCK, batch_ndim: int = 0,
               use_pallas: bool = True, interpret: bool | None = None):
    """Inverse of :func:`quantize`: fp32 array of ``shape``."""
    from repro.kernels.ref import dequantize_blocks_ref
    if not use_pallas:
        y2d = dequantize_blocks_ref(q, scales)
    else:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        y2d = dequantize_blocks(q, scales, interpret=interpret)
    return _from_blocks(y2d, shape, batch_ndim)


def fake_quantize(x, *, block: int = BLOCK, batch_ndim: int = 0,
                  use_pallas: bool = True, interpret: bool | None = None):
    """dequantize(quantize(x)) — the value a receiver would reconstruct.

    fp32, same shape as ``x``. This is what the in-process sync simulation
    feeds to ``mean_fn``; ``x - fake_quantize(x)`` is the error-feedback
    residual.
    """
    q, s = quantize(x, block=block, batch_ndim=batch_ndim,
                    use_pallas=use_pallas, interpret=interpret)
    return dequantize(q, s, x.shape, block=block, batch_ndim=batch_ndim,
                      use_pallas=use_pallas, interpret=interpret)
