"""Shared padding / blocked-view helpers for the Pallas kernels.

Every kernel in this package tiles flat payloads the same way: pad the
leading (row) axis to a grid-tile multiple, or flatten an arbitrary leaf to
``(nblocks, block)`` rows that never straddle the leading per-worker axes.
These helpers used to live in ``kernels/quantize.py`` (with
``kernels/sync_fused.py`` and ``kernels/adaalter_update.py`` each carrying
their own variants); they are now shared here so the row/block layout — the
thing the bitwise guarantees between the per-leaf and flat paths hinge on —
is defined exactly once.

``quantize.py`` re-exports ``_pad_rows``/``_to_blocks``/``_from_blocks`` as
aliases for back-compat with existing imports.
"""
from __future__ import annotations

import jax.numpy as jnp

LANES = 128               # native VPU lane width: last axis of every tile


def pad_rows(a, tile: int):
    """Zero-pad axis 0 of ``a`` up to a multiple of ``tile`` rows."""
    pad = (-a.shape[0]) % tile
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)) if pad else a


def to_blocks(x, block: int, batch_ndim: int):
    """Flatten to (nblocks, block), zero-padded; blocks never straddle the
    leading ``batch_ndim`` axes (the per-worker payload boundary)."""
    lead = 1
    for d in x.shape[:batch_ndim]:
        lead *= d
    flat = x.reshape(lead, -1) if batch_ndim else x.reshape(1, -1)
    pad = (-flat.shape[1]) % block
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat.reshape(-1, block)


def from_blocks(y2d, shape, batch_ndim: int):
    """Inverse of :func:`to_blocks`: strip the per-lead padding and restore
    ``shape``. The one place the blocked layout is decoded — the quantize
    pair, the fused EF kernel and the flat-plane packer all go through it."""
    lead = 1
    for d in shape[:batch_ndim]:
        lead *= d
    body = 1
    for d in shape[batch_ndim:]:
        body *= d
    return y2d.reshape(lead, -1)[:, :body].reshape(shape)


def padded_size(n: int, align: int) -> int:
    """``n`` rounded up to a multiple of ``align`` (elements)."""
    return n + (-n) % align


def round_through_bf16(x):
    """Nearest-bfloat16 value of fp32 ``x``, as fp32 — and guaranteed to
    STAY rounded.

    The flat-plane paths keep bf16 leaves as fp32 planes and encode the
    per-step rounding as a convert chain; XLA's excess-precision
    simplification (on by default) is allowed to drop exactly that chain
    when it fuses into a larger program, silently keeping fp32 values the
    per-leaf layout would have rounded — half-ulp drift that breaks the
    bitwise contract. The optimization barrier pins the bf16 intermediate
    so the simplifier cannot see through it. (The Pallas kernels don't need
    this: a ``pallas_call`` body is opaque to the XLA simplifier.)
    """
    import jax
    return jax.lax.optimization_barrier(
        x.astype(jnp.bfloat16)).astype(jnp.float32)
