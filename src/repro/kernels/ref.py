"""Pure-jnp oracle for the fused AdaAlter update kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_update_ref(x, g, b2_sync, b2_local, eta, extra):
    """y = x − η·g/sqrt(b2_sync + extra);  b2_local += g²  (all math fp32)."""
    g32 = g.astype(jnp.float32)
    denom = jnp.sqrt(b2_sync.astype(jnp.float32) + jnp.asarray(extra, jnp.float32))
    y = (x.astype(jnp.float32)
         - jnp.asarray(eta, jnp.float32) * g32 / denom).astype(x.dtype)
    new_b2 = b2_local.astype(jnp.float32) + g32 * g32
    return y, new_b2


def quantize_blocks_ref(x2d):
    """Symmetric per-block int8 quantization oracle.

    x2d: (nblocks, block) — one quantization block per row.
    Returns (q int8 (nblocks, block), scales fp32 (nblocks, 1)); the math
    is THE shared ``quantize.block_quantize`` definition, so oracle and
    kernels cannot drift apart.
    """
    from repro.kernels.quantize import block_quantize
    return block_quantize(x2d.astype(jnp.float32))


def dequantize_blocks_ref(q2d, scales):
    """Inverse of :func:`quantize_blocks_ref`: x̂ = q · scale (fp32)."""
    return q2d.astype(jnp.float32) * scales


def fused_ef_blocks_ref(x2d, e2d, *, clamp_nonneg: bool = False,
                        out_dtype=None):
    """Oracle for the fused error-feedback sync encode (sync_fused.py).

    The three-pass composition the fused kernel replaces, written out:
    v = x + e; (q, s) = quantize(v); v̂ = dequantize(q, s) [clamped >= 0 for
    accumulator payloads]; wire = v̂ cast to the payload dtype;
    residual' = v − wire. Returns (wire, residual').
    """
    import jax

    v = x2d.astype(jnp.float32) + e2d
    q, s = quantize_blocks_ref(v)
    vhat = dequantize_blocks_ref(q, s)
    # same lower clamp as the kernel: >= 0 for accumulator payloads, else a
    # value-preserving pin that keeps v − q·s from contracting into an FMA
    vhat = jnp.maximum(vhat, 0.0 if clamp_nonneg
                       else float(jnp.finfo(jnp.float32).min))
    # barrier: the wire cast must stay materialized (excess precision would
    # otherwise let the residual subtract the unrounded dequantized value)
    w = jax.lax.optimization_barrier(vhat.astype(out_dtype or x2d.dtype))
    return w, v - w.astype(jnp.float32)


def flat_fused_update_ref(plane, g_plane, bs_plane, bl_plane, eta, extra,
                          rnd16):
    """jnp fallback for the flat-plane Local AdaAlter step — the SAME bits
    the per-leaf non-Pallas path (``LocalOptimizer.local_step`` under vmap)
    produces: that path computes the update in fp32, casts it to the param
    dtype, and subtracts in the param dtype, so bf16 slots (``rnd16``) go
    through ``bf16(x) − bf16(upd)`` here rather than rounding the fp32
    difference (which is what the Pallas pair does — the two fallbacks
    mirror their respective kernels, not each other)."""
    import jax

    upd = jnp.asarray(eta, jnp.float32) * g_plane / jnp.sqrt(
        bs_plane + jnp.asarray(extra, jnp.float32))
    y32 = plane - upd
    # barriers pin the bf16 roundings (operand cast AND result) against
    # XLA's excess-precision simplification — see tiling.round_through_bf16
    ub = jax.lax.optimization_barrier(upd.astype(jnp.bfloat16))
    y16 = jax.lax.optimization_barrier(
        plane.astype(jnp.bfloat16) - ub).astype(jnp.float32)
    y = jnp.where(rnd16, y16, y32)
    return y, bl_plane + jnp.square(g_plane)


def flat_ef_blocks_ref(x2d, e2d, rnd, low):
    """Oracle for the flat EF sync kernel (sync_fused._flat_ef_kernel):
    per-block int8 roundtrip with per-block lower clamp and per-block
    bf16 wire rounding, all fp32 in/out."""
    from repro.kernels.tiling import round_through_bf16

    v = x2d + e2d
    q, s = quantize_blocks_ref(v)
    vhat = jnp.maximum(dequantize_blocks_ref(q, s), low)
    w = jnp.where(rnd > 0, round_through_bf16(vhat), vhat)
    return w, v - w


def ssd_ref(xbar, Bm, Cm, dA):
    """Pure-jnp oracle for the SSD chunk scan (mirrors models/ssm.py math).

    xbar: (B,NZ,c,NH,hd)  Bm/Cm: (B,NZ,c,N)  dA: (B,NZ,c,NH) -> y fp32.
    """
    import jax
    b, nz, c, nh, hd = xbar.shape
    xbar = xbar.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=2)
    tri = jnp.tril(jnp.ones((c, c), bool))
    CB = jnp.einsum("bzln,bzsn->bzls", Cm, Bm)
    logdecay = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    logdecay = jnp.where(tri[None, None, :, :, None], logdecay, -jnp.inf)
    M = CB[..., None] * jnp.exp(logdecay)
    y = jnp.einsum("bzlsh,bzshp->bzlhp", M, xbar)
    seg = jnp.exp(cum[:, :, -1:, :] - cum)
    chunk_states = jnp.einsum("bzsn,bzsh,bzshp->bzhnp", Bm, seg, xbar)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def scan_fn(S, inp):
        st, dk = inp
        return S * dk[..., None, None] + st, S

    S0 = jnp.zeros((b, nh, Bm.shape[-1], hd), jnp.float32)
    _, S_before = jax.lax.scan(
        scan_fn, S0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_before = S_before.transpose(1, 0, 2, 3, 4)
    return y + jnp.einsum("bzln,bzlh,bzhnp->bzlhp", Cm, jnp.exp(cum), S_before)
