"""Pure-jnp oracle for the fused AdaAlter update kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_update_ref(x, g, b2_sync, b2_local, eta, extra):
    """y = x − η·g/sqrt(b2_sync + extra);  b2_local += g²  (all math fp32)."""
    g32 = g.astype(jnp.float32)
    denom = jnp.sqrt(b2_sync.astype(jnp.float32) + jnp.asarray(extra, jnp.float32))
    y = (x.astype(jnp.float32)
         - jnp.asarray(eta, jnp.float32) * g32 / denom).astype(x.dtype)
    new_b2 = b2_local.astype(jnp.float32) + g32 * g32
    return y, new_b2


def quantize_blocks_ref(x2d):
    """Symmetric per-block int8 quantization oracle.

    x2d: (nblocks, block) — one quantization block per row.
    Returns (q int8 (nblocks, block), scales fp32 (nblocks, 1)) with
    scale = max|block| / 127 and q = round(x / scale) ∈ [−127, 127]
    (all-zero blocks get scale 0 and quantize to 0).
    """
    x = x2d.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(x * inv), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_blocks_ref(q2d, scales):
    """Inverse of :func:`quantize_blocks_ref`: x̂ = q · scale (fp32)."""
    return q2d.astype(jnp.float32) * scales


def fused_ef_blocks_ref(x2d, e2d, *, clamp_nonneg: bool = False,
                        out_dtype=None):
    """Oracle for the fused error-feedback sync encode (sync_fused.py).

    The three-pass composition the fused kernel replaces, written out:
    v = x + e; (q, s) = quantize(v); v̂ = dequantize(q, s) [clamped >= 0 for
    accumulator payloads]; wire = v̂ cast to the payload dtype;
    residual' = v − wire. Returns (wire, residual').
    """
    v = x2d.astype(jnp.float32) + e2d
    q, s = quantize_blocks_ref(v)
    vhat = dequantize_blocks_ref(q, s)
    # same lower clamp as the kernel: >= 0 for accumulator payloads, else a
    # value-preserving pin that keeps v − q·s from contracting into an FMA
    vhat = jnp.maximum(vhat, 0.0 if clamp_nonneg
                       else float(jnp.finfo(jnp.float32).min))
    w = vhat.astype(out_dtype or x2d.dtype)
    return w, v - w.astype(jnp.float32)


def ssd_ref(xbar, Bm, Cm, dA):
    """Pure-jnp oracle for the SSD chunk scan (mirrors models/ssm.py math).

    xbar: (B,NZ,c,NH,hd)  Bm/Cm: (B,NZ,c,N)  dA: (B,NZ,c,NH) -> y fp32.
    """
    import jax
    b, nz, c, nh, hd = xbar.shape
    xbar = xbar.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=2)
    tri = jnp.tril(jnp.ones((c, c), bool))
    CB = jnp.einsum("bzln,bzsn->bzls", Cm, Bm)
    logdecay = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    logdecay = jnp.where(tri[None, None, :, :, None], logdecay, -jnp.inf)
    M = CB[..., None] * jnp.exp(logdecay)
    y = jnp.einsum("bzlsh,bzshp->bzlhp", M, xbar)
    seg = jnp.exp(cum[:, :, -1:, :] - cum)
    chunk_states = jnp.einsum("bzsn,bzsh,bzshp->bzhnp", Bm, seg, xbar)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def scan_fn(S, inp):
        st, dk = inp
        return S * dk[..., None, None] + st, S

    S0 = jnp.zeros((b, nh, Bm.shape[-1], hd), jnp.float32)
    _, S_before = jax.lax.scan(
        scan_fn, S0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_before = S_before.transpose(1, 0, 2, 3, 4)
    return y + jnp.einsum("bzln,bzlh,bzhnp->bzlhp", Cm, jnp.exp(cum), S_before)
