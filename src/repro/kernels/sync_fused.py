"""Pallas TPU kernel: fused error-feedback sync-payload encode.

The sync round's device-side work — the part Stich (2018) says must be
near-free for local SGD's speedup to survive — was previously three separate
HBM passes over the full payload (``core.optimizers.compressed_sync``):

    pass 1   v    = x + e            (error-feedback add)
    pass 2   q, s = quantize(v)      (per-block int8 + fp32 scales)
    pass 3   v̂    = dequantize(q, s) ; e' = v − v̂   (residual update)

This kernel fuses all of it into ONE pass: read (x, e), write (wire, e') —
the int8/scales intermediates never leave VMEM. The wire output is the
dequantized value cast to the payload dtype (exactly what the in-process
sync mean averages), and the residual is computed against that cast value,
so the fused path is **bitwise identical** to the three-pass composition
(asserted in tests/test_sync_fused.py against ``kernels/ref.py``).

Layout mirrors ``quantize.py``: payloads are flattened (never straddling the
leading ``batch_ndim`` worker axes), zero-padded to a multiple of BLOCK and
viewed as ``(nblocks, BLOCK)`` — one quantization block per row — with a 1-D
grid over row tiles. ``clamp_nonneg`` (the B² accumulators feed rsqrt) is a
static kernel variant. On CPU (this container) the kernel runs in
``interpret=True`` mode; on TPU the same code compiles to Mosaic
(TILE_BLOCKS=512 keeps every store tile a multiple of the fp32 (8,128) and
bf16 (16,128) tilings).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import BLOCK, TILE_BLOCKS, block_quantize
from repro.kernels.tiling import from_blocks as _from_blocks
from repro.kernels.tiling import pad_rows as _pad_rows
from repro.kernels.tiling import to_blocks as _to_blocks

__all__ = ["fused_ef_blocks", "fused_ef_leaf", "flat_ef_plane",
           "BLOCK", "TILE_BLOCKS"]


def _fused_kernel(x_ref, e_ref, w_ref, r_ref, *, clamp_nonneg: bool):
    v = x_ref[...].astype(jnp.float32) + e_ref[...]
    # per-row (per-block) symmetric int8 quantization — THE shared
    # definition (quantize.block_quantize) so the fusion stays bitwise
    q, scale = block_quantize(v)
    vhat = q.astype(jnp.float32) * scale
    # The lower clamp is load-bearing twice over: accumulator payloads feed
    # rsqrt and must stay >= 0, and for plain payloads the (value-preserving)
    # max against float32 min keeps the backend from contracting the
    # following v − q·scale into an FMA — which would skip the product's
    # rounding and drift the residual half an ulp off the three-pass
    # composition, whose dequantized wire is materialized at a kernel
    # boundary. With the max in between, both paths subtract the same
    # rounded value and the bitwise match holds at any payload size.
    lower = 0.0 if clamp_nonneg else float(jnp.finfo(jnp.float32).min)
    vhat = jnp.maximum(vhat, lower)
    w = vhat.astype(w_ref.dtype)
    w_ref[...] = w
    # residual vs what is ACTUALLY sent (incl. any bf16 wire cast)
    r_ref[...] = v - w.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("clamp_nonneg", "out_dtype",
                                             "tile_blocks", "interpret"))
def fused_ef_blocks(x2d, e2d, *, clamp_nonneg: bool = False, out_dtype=None,
                    tile_blocks: int = TILE_BLOCKS, interpret: bool = False):
    """One-pass EF encode of a (nblocks, block) view.

    Returns ``(wire, new_residual)``: wire is ``out_dtype`` (default: x2d's
    dtype) holding decode(encode(x+e)); new_residual is fp32 (x+e) − wire.
    """
    nb, block = x2d.shape
    out_dtype = jnp.dtype(out_dtype or x2d.dtype)
    xp = _pad_rows(x2d, tile_blocks)
    ep = _pad_rows(e2d, tile_blocks)
    grid = (xp.shape[0] // tile_blocks,)
    bspec = pl.BlockSpec((tile_blocks, block), lambda i: (i, 0))
    w, r = pl.pallas_call(
        functools.partial(_fused_kernel, clamp_nonneg=clamp_nonneg),
        grid=grid,
        in_specs=[bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, out_dtype),
                   jax.ShapeDtypeStruct(xp.shape, jnp.float32)],
        interpret=interpret,
    )(xp, ep)
    return w[:nb], r[:nb]


def fused_ef_leaf(x, e, *, block: int = BLOCK, batch_ndim: int = 0,
                  clamp_nonneg: bool = False, use_pallas: bool = True,
                  interpret: bool | None = None):
    """Fused EF encode of one arbitrarily-shaped payload leaf.

    ``x`` is the payload (any float dtype), ``e`` the fp32 residual of the
    same shape. Returns ``(wire, new_residual)`` shaped like ``x``: wire in
    x's dtype (what goes into the sync mean), new_residual fp32.
    ``use_pallas=False`` runs the pure-jnp oracle (kernels/ref.py) on the
    same blocked view — still a single jitted program, just not hand-tiled.
    """
    batch_ndim = min(batch_ndim, x.ndim)
    x2d = _to_blocks(x, block, batch_ndim)
    e2d = _to_blocks(e, block, batch_ndim)
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        w2d, r2d = fused_ef_blocks(x2d, e2d, clamp_nonneg=clamp_nonneg,
                                   out_dtype=x.dtype, interpret=interpret)
    else:
        from repro.kernels.ref import fused_ef_blocks_ref
        w2d, r2d = fused_ef_blocks_ref(x2d, e2d, clamp_nonneg=clamp_nonneg,
                                       out_dtype=x.dtype)

    return (_from_blocks(w2d, x.shape, batch_ndim).astype(x.dtype),
            _from_blocks(r2d, x.shape, batch_ndim).astype(jnp.float32))


# --------------------------------------------------------------------------- #
# flat-plane variant: ONE kernel for the whole sync payload
# --------------------------------------------------------------------------- #
def _flat_ef_kernel(x_ref, e_ref, rnd_ref, low_ref, w_ref, r_ref):
    """Same math as :func:`_fused_kernel`, with the two per-leaf static
    choices turned into per-block fp32 sidecars so one launch covers the
    whole [params ‖ B²] payload plane:

      ``low``  the lower clamp — 0 for accumulator blocks (they feed
               rsqrt), float32-min for parameter blocks (the
               value-preserving FMA pin, see the fused kernel's comment);
      ``rnd``  >0 where the leaf dtype is bfloat16 — the wire value rounds
               through bf16 exactly like the per-leaf ``astype(w_ref.dtype)``
               store, so wire AND residual bits match the per-leaf kernel.
    """
    v = x_ref[...] + e_ref[...]
    q, scale = block_quantize(v)
    vhat = q.astype(jnp.float32) * scale
    vhat = jnp.maximum(vhat, low_ref[...])
    w16 = vhat.astype(jnp.bfloat16).astype(jnp.float32)
    w = jnp.where(rnd_ref[...] > 0, w16, vhat)
    w_ref[...] = w
    r_ref[...] = v - w


@functools.partial(jax.jit, static_argnames=("block", "tile_blocks",
                                             "interpret"))
def flat_ef_blocks(x2d, e2d, rnd, low, *, block: int = BLOCK,
                   tile_blocks: int = TILE_BLOCKS, interpret: bool = False):
    """One-pass EF encode of a whole payload plane viewed as blocks.

    ``x2d``/``e2d`` are (nblocks, block) fp32; ``rnd``/``low`` are the
    (nblocks, 1) fp32 sidecars. Returns (wire, new_residual), both fp32 —
    the wire already rounded through bf16 where ``rnd`` says so.
    """
    nb = x2d.shape[0]
    xp = _pad_rows(x2d, tile_blocks)
    ep = _pad_rows(e2d, tile_blocks)
    rp = _pad_rows(rnd, tile_blocks)
    lp = _pad_rows(low, tile_blocks)
    grid = (xp.shape[0] // tile_blocks,)
    bspec = pl.BlockSpec((tile_blocks, block), lambda i: (i, 0))
    sspec = pl.BlockSpec((tile_blocks, 1), lambda i: (i, 0))
    w, r = pl.pallas_call(
        _flat_ef_kernel,
        grid=grid,
        in_specs=[bspec, bspec, sspec, sspec],
        out_specs=[bspec, bspec],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, jnp.float32),
                   jax.ShapeDtypeStruct(xp.shape, jnp.float32)],
        interpret=interpret,
    )(xp, ep, rp, lp)
    return w[:nb], r[:nb]


def flat_ef_plane(plane, residual, rnd_blocks, low_blocks, *,
                  block: int = BLOCK, use_pallas: bool = True,
                  fused: bool = True, interpret: bool | None = None):
    """Fused EF encode of one whole (..., M) payload plane — the flat
    path's ONE device-side sync kernel (M must be a multiple of ``block``;
    FlatSpace slot alignment guarantees it, so blocks never straddle leaves
    or workers and every real element lands in exactly the block the
    per-leaf encode would put it in).

    ``rnd_blocks``/``low_blocks`` are (M // block, 1) per-block sidecars
    for ONE plane row; they are tiled across the leading axes here. Under
    a sharded plane this runs shard-local inside ``shard_map``: the caller
    passes the LOCAL payload plus per-shard sidecar views (slices indexed
    relative to the shard origin), and because shard boundaries land on
    tile (hence block) boundaries the blocked view partitions the same
    elements as the replicated call.
    ``fused=False`` composes the same numerics from the three-pass
    quantize/dequantize pipeline (bitwise identical — the bench/debug
    fallback, still one collective). Returns (wire_plane, new_residual),
    both fp32 shaped like ``plane``.
    """
    shape = plane.shape
    assert shape[-1] % block == 0, (shape, block)
    lead = 1
    for d in shape[:-1]:
        lead *= d
    x2d = plane.reshape(-1, block)
    e2d = residual.reshape(-1, block)
    rnd = jnp.tile(jnp.asarray(rnd_blocks, jnp.float32), (lead, 1))
    low = jnp.tile(jnp.asarray(low_blocks, jnp.float32), (lead, 1))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if fused and use_pallas:
        w2d, r2d = flat_ef_blocks(x2d, e2d, rnd, low, block=block,
                                  interpret=interpret)
    elif fused:
        from repro.kernels.ref import flat_ef_blocks_ref
        w2d, r2d = flat_ef_blocks_ref(x2d, e2d, rnd, low)
    else:
        # three-pass composition over the same blocked view (mirrors the
        # generic ef_apply path, incl. its separately-materialized v̂)
        from repro.kernels.quantize import dequantize_blocks, quantize_blocks
        from repro.kernels.ref import (dequantize_blocks_ref,
                                       quantize_blocks_ref)
        v = x2d + e2d
        if use_pallas:
            q, s = quantize_blocks(v, interpret=interpret)
            vhat = dequantize_blocks(q, s, interpret=interpret)
        else:
            q, s = quantize_blocks_ref(v)
            vhat = dequantize_blocks_ref(q, s)
        from repro.kernels.tiling import round_through_bf16
        vhat = jnp.maximum(vhat, low)
        w2d = jnp.where(rnd > 0, round_through_bf16(vhat), vhat)
        r2d = v - w2d
    return w2d.reshape(shape), r2d.reshape(shape)
