"""Pallas TPU kernel: fused Mamba-2 SSD chunk scan (forward).

The pure-jnp SSD in ``repro/models/ssm.py`` materializes ~10 chunk-shaped
intermediates per layer in HBM (logdecay/M/seg/chunk_states/...), which is
why mamba2-370m is memory-bound 70:1 at train_4k (§Roofline). This kernel
keeps the recurrent state S (nh, N, hd per batch-head) in VMEM across the
sequential chunk dimension of the grid, so HBM traffic collapses to the x/y
streams plus the per-chunk B/C/dt loads.

Layout: grid = (B, NH, NZ) with the chunk axis LAST and marked "arbitrary"
(sequential) — Pallas TPU keeps scratch alive across sequential grid steps,
which is exactly the cross-chunk state carry. Each step processes one
(chunk, head) tile:

  in:  x (c, hd), B (c, N), C (c, N), dA (c,)           [VMEM blocks]
  scratch: S (N, hd) f32                                 [persists over NZ]
  intra: M = (C B^T) ⊙ exp(cum(dA) outer-diff), y = M @ (x·dt)
  inter: y += exp(cum) · (C @ S);  S = exp(cum_last)·S + B^T diag(seg) xbar

Forward-only: used for the serving/prefill path; training keeps the jnp
path (a bwd kernel is future work — see EXPERIMENTS.md §Perf).
Validated in interpret mode against ``repro.kernels.ref.ssd_ref`` across
shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# JAX 0.4.x ships the TPU compiler knobs as ``TPUCompilerParams``; newer
# releases renamed it to ``CompilerParams``. Accept either.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams


def _kernel(x_ref, b_ref, c_ref, da_ref, y_ref, s_ref):
    """One (batch, head, chunk) tile. Shapes:
    x (1,1,1,c,hd), b (1,1,c,N), c (1,1,c,N), da (1,1,1,c); y like x;
    s scratch (N, hd) f32. The D-skip term is elementwise and stays outside.
    """
    nz = pl.program_id(2)

    @pl.when(nz == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)               # (c, hd)  = dt·x pre-scaled
    B = b_ref[0, 0].astype(jnp.float32)                  # (c, N)
    C = c_ref[0, 0].astype(jnp.float32)                  # (c, N)
    dA = da_ref[0, 0, 0].astype(jnp.float32)             # (c,)
    cum = jnp.cumsum(dA)                                 # (c,)

    # intra-chunk dual form
    CB = C @ B.T                                         # (c, c)
    ld = cum[:, None] - cum[None, :]                     # (c, c)
    c_len = x.shape[0]
    tri = jnp.tril(jnp.ones((c_len, c_len), jnp.bool_))
    M = jnp.where(tri, CB * jnp.exp(ld), 0.0)
    y = M @ x                                            # (c, hd)

    # inter-chunk: contribution of the carried state, then update it
    S = s_ref[...]
    y = y + jnp.exp(cum)[:, None] * (C @ S)              # (c, hd)
    seg = jnp.exp(cum[-1] - cum)                         # decay to chunk end
    s_ref[...] = jnp.exp(cum[-1]) * S + B.T @ (seg[:, None] * x)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(xbar, Bm, Cm, dA, *, interpret: bool = True):
    """Fused SSD forward (no D-skip — that term is elementwise, caller adds).

    xbar: (B, NZ, c, NH, hd) — dt-scaled inputs (x * dt)
    Bm/Cm: (B, NZ, c, N)
    dA:   (B, NZ, c, NH)    — dt * A (negative)
    returns y: (B, NZ, c, NH, hd) fp32
    """
    b, nz, c, nh, hd = xbar.shape
    n = Bm.shape[-1]
    # kernel-friendly layout: head-major so each tile is contiguous
    x_t = xbar.transpose(0, 3, 1, 2, 4)                  # (B, NH, NZ, c, hd)
    da_t = dA.transpose(0, 3, 1, 2)                      # (B, NH, NZ, c)

    grid = (b, nh, nz)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, c, hd), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda i, j, k: (i, j, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, c, hd), lambda i, j, k: (i, j, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, nz, c, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_t, Bm, Cm, da_t)
    return y.transpose(0, 2, 3, 1, 4)                    # (B, NZ, c, NH, hd)
