from repro.sharding.partition import (
    DEFAULT_RULES,
    ShardingRules,
    active_rules,
    constraint,
    use_rules,
)

__all__ = ["DEFAULT_RULES", "ShardingRules", "active_rules", "constraint", "use_rules"]
