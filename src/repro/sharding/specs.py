"""Per-leaf parameter sharding specs derived from pytree paths.

Maps every parameter leaf (by its name/ancestry in the param pytree) to
logical axes, resolves those through :class:`ShardingRules`, prepends the
local-SGD worker axis where applicable, and drops mesh axes that do not
divide the concrete dimension (shape-safe).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.sharding.partition import ShardingRules, plane_shard_axes

_STACKED_ROOTS = ("blocks", "encoder")

_BY_NAME = {
    "embed": ("vocab", "embed_fsdp"),
    "lm_head": ("embed_fsdp", "vocab"),
    "head_w": ("embed_fsdp", "vocab"),
    "head_b": ("vocab",),
    "wq": ("embed_fsdp", "q_heads"),
    "wk": ("embed_fsdp", "q_heads"),
    "wv": ("embed_fsdp", "q_heads"),
    "wo": ("q_heads", "embed_fsdp"),
    "bq": ("q_heads",),
    "bk": ("q_heads",),
    "bv": ("q_heads",),
    "in_proj": ("embed_fsdp", "ssm_inner"),
    "conv_w": (None, "ssm_inner"),
    "out_proj": ("ssm_inner", "embed_fsdp"),
    "norm": ("ssm_inner",),
    "router": ("embed_fsdp", None),
    "wx": ("embed_fsdp", "lstm_hidden"),
    "wh": ("embed_fsdp", "lstm_hidden"),
    "b": ("lstm_hidden",),
    "wp": ("lstm_hidden", "embed_fsdp"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(f"[{k.idx}]")
    return tuple(names)


def logical_for_leaf(path, leaf, *, skip_leading: int = 0) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter leaf.

    ``skip_leading``: number of leading non-semantic axes (e.g. the local-SGD
    worker axis) to EXCLUDE — the returned tuple covers only
    ``leaf.shape[skip_leading:]``.
    """
    names = _path_names(path)
    name = names[-1]
    in_moe = "moe" in names
    stacked = names[0] in _STACKED_ROOTS

    if name in ("w1", "w3"):
        log = (("experts", "embed_fsdp", "mlp") if in_moe
               else ("embed_fsdp", "mlp"))
    elif name == "w2":
        log = (("experts", "mlp", "embed_fsdp") if in_moe
               else ("mlp", "embed_fsdp"))
    elif name in _BY_NAME:
        log = _BY_NAME[name]
    else:
        log = ()                                         # norms, gates, scalars

    rank = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
    rank -= skip_leading
    body = rank - (1 if stacked else 0)
    log = tuple(log)[:body]
    log = (None,) * (body - len(log)) + log if len(log) < body else log
    if stacked:
        log = (None,) + log
    return log


def shape_safe_spec(shape: Sequence[int], spec: P, mesh) -> P:
    """Drop mesh axes whose product does not divide the dimension."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            n = mesh.shape[a]
            if dim % (prod * n) == 0:
                kept.append(a)
                prod *= n
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def param_shardings(rules: ShardingRules, params: Any, *,
                    with_workers: bool = False) -> Any:
    """NamedSharding pytree parallel to ``params``.

    ``with_workers=True`` expects every leaf to carry a leading local-SGD
    worker axis (sharded over the plan's local axes).
    """
    mesh = rules.mesh
    worker_axes = tuple(rules.plan.local_axes)

    def one(path, leaf):
        log = logical_for_leaf(path, leaf, skip_leading=1 if with_workers else 0)
        spec = rules.resolve(log)
        if with_workers:
            body_shape = leaf.shape[1:]
            spec = shape_safe_spec(body_shape, spec, mesh)
            w = worker_axes if worker_axes else None
            w = w if not isinstance(w, tuple) or len(w) > 1 else w[0]
            spec = P(w, *tuple(spec))
        else:
            spec = shape_safe_spec(leaf.shape, spec, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(rules: ShardingRules, opt_state, param_sh, *,
                        with_workers: bool = False):
    """Optimizer-state shardings.

    Our optimizer states are flat dicts: scalar counters (``step``,
    ``tprime``) plus accumulator pytrees (``b2`` / ``b2_sync`` /
    ``b2_local``, and ``res_params`` / ``res_b2`` error-feedback residuals
    under quantized sync) that mirror the parameter tree exactly — so
    accumulators reuse the parameter shardings verbatim.
    """
    mesh = rules.mesh
    worker_axes = tuple(rules.plan.local_axes)
    w = (worker_axes if len(worker_axes) > 1
         else (worker_axes[0] if worker_axes else None))
    scalar = NamedSharding(mesh, P(w) if (with_workers and w) else P())
    out = {}
    for k, v in opt_state.items():
        out[k] = scalar if k in ("step", "tprime") else param_sh
    return out


# --------------------------------------------------------------------------- #
# flat parameter plane (core/flatspace.py) shardings
# --------------------------------------------------------------------------- #
def _axis_entry(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def plane_shard_count(mesh, plan) -> int:
    """Number of tile-aligned sub-planes the flat plane splits into."""
    n = 1
    for a in plane_shard_axes(mesh, plan):
        n *= mesh.shape[a]
    return n


def plane_shardings(mesh, plan):
    """NamedShardings for the flat train-state planes.

    Returns ``(plane, scalar, shard_axes)``: the plane sharding puts the
    worker (local-SGD) axes on the leading dim and the FSDP/TP shard axes
    on the element dim — each device holds one contiguous, tile-aligned
    sub-plane per worker row. ``shard_axes == ()`` reproduces the PR-4
    replicated plane exactly (``P(workers, None)``).
    """
    shard_axes = plane_shard_axes(mesh, plan)
    w = _axis_entry(tuple(plan.local_axes))
    s = _axis_entry(shard_axes)
    plane = NamedSharding(mesh, P(w, s))
    scalar = NamedSharding(mesh, P(w))
    return plane, scalar, shard_axes
