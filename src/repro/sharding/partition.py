"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

Models annotate activations/params with *logical* axis names; a
:class:`ShardingRules` context maps those to physical mesh axes. Outside any
context (unit tests, single-device runs) the annotations are no-ops.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: Dict[str, Optional[str]] = {
    # activations
    "workers": "__local__",        # resolved to the plan's local axes
    "batch": "__data__",           # resolved to grad/fsdp axes ("data")
    "seq": None,
    "seq_sp": None,             # residual-stream seq axis (=model under seq_parallel)
    "embed": None,
    "q_heads": "model",
    "kv_heads": "model",
    "heads_tp": "model",           # padded/repeated attention heads (§Perf)
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "capacity": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "frames": None,
    "image": None,
    # weights
    "embed_fsdp": "__fsdp__",      # embed dim of weights, ZeRO-sharded
    "lstm_hidden": "model",
}


class ShardingRules:
    def __init__(self, mesh: Mesh, plan, overrides: Optional[Dict[str, Optional[str]]] = None):
        self.mesh = mesh
        self.plan = plan
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)

    def resolve(self, logical: Sequence[Optional[str]]) -> P:
        axes = []
        used = set()
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            ax = self.rules.get(name, None)
            if ax == "__local__":
                ax = tuple(self.plan.local_axes) or None
            elif ax == "__data__":
                ax = tuple(a for a in self.plan.grad_axes) or None
            elif ax == "__fsdp__":
                ax = tuple(self.plan.fsdp_axes) or None
            if isinstance(ax, str):
                ax = (ax,)
            if ax:
                ax = tuple(a for a in ax if a in self.mesh.shape and a not in used)
                used.update(ax)
                axes.append(ax if len(ax) > 1 else ax[0] if ax else None)
            else:
                axes.append(None)
        return P(*axes)

    def named_sharding(self, logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(logical))


def plane_shard_axes(mesh: Mesh, plan) -> Tuple[str, ...]:
    """Mesh axes the flat parameter plane shards its element dim over.

    Derived from the SAME plan fields the per-leaf path uses: the FSDP
    (ZeRO) axes plus the tensor-parallel axis, in that order — minus the
    worker (``local_axes``) dims, which shard the plane's leading axis, and
    minus axes the mesh doesn't carry (or carries at size 1, where sharding
    is a no-op). Empty result = the PR-4 replicated plane.
    """
    local = set(plan.local_axes)
    cand = tuple(plan.fsdp_axes)
    if getattr(plan, "tp_axis", ""):
        cand = cand + (plan.tp_axis,)
    out, seen = [], set()
    for a in cand:
        if (a and a in mesh.shape and mesh.shape[a] > 1
                and a not in local and a not in seen):
            out.append(a)
            seen.add(a)
    return tuple(out)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


def constraint(x, logical: Sequence[Optional[str]]):
    """Annotate an intermediate with logical axes (no-op without rules)."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.resolve(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
