"""Qwen2-7B — dense decoder, GQA with QKV bias.

[arXiv:2407.10671; 28 layers, d_model=3584, 28 heads / 4 kv heads,
 d_ff=18944, vocab=152064, qkv bias]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="arXiv:2407.10671",
)
