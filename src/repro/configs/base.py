"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; input
shapes as :class:`ShapeConfig`; the paper's optimizers as
:class:`OptimizerConfig`; and the distribution strategy as a
:class:`ParallelismPlan` resolved against a concrete mesh at launch time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

Family = str  # 'dense' | 'moe' | 'ssm' | 'audio' | 'vlm' | 'hybrid' | 'lstm'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (transformer backbone or LSTM)."""

    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"                    # 'swiglu' | 'gelu' | 'relu'
    # --- MoE ---
    n_experts: int = 0                     # 0 -> dense FFN
    top_k: int = 1
    moe_every: int = 1                     # MoE layer every k-th layer
    dense_d_ff: int = 0                    # FFN width of non-MoE layers (0 -> d_ff)
    shared_expert: bool = False            # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0                     # N (state dim); 0 -> no SSM path
    ssm_expand: int = 2                    # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_chunk: int = 64                    # SSD chunk length
    ssm_conv: int = 4                      # depthwise conv width
    # --- hybrid (hymba): both attn and ssm paths in parallel ---
    hybrid: bool = False
    # --- enc-dec (audio) ---
    n_encoder_layers: int = 0              # >0 -> encoder-decoder model
    # --- VLM ---
    cross_attn_every: int = 0              # >0 -> cross-attn layer every k-th layer
    n_image_tokens: int = 0                # patch-embedding tokens per sample (stub frontend)
    # --- attention variants ---
    sliding_window: int = 0                # 0 -> full causal attention
    long_context_mode: str = ""            # '' | 'sliding_window' | 'ssm'
    # --- LSTM (paper's Big LSTM) ---
    lstm_proj: int = 0                     # LSTM-2048-512 projection size
    # --- beyond-paper performance knobs (default False == paper-faithful
    #     baseline; flipped by the '+opt' configs measured in §Perf) ---
    attn_tp_pad: bool = False       # pad/repeat heads to divide the TP axis
    attn_remat: bool = False        # flash-style recompute of attention bwd
    fused_xent: bool = False        # sharded xent, no gathered logits, bf16 dL
    moe_group_tokens: bool = False  # per-shard MoE dispatch (no T x E x C one-hots)
    seq_parallel: bool = False      # Megatron-SP: residual stream sharded over TP
    attn_bf16_probs: bool = False   # bf16 P·V in the flash scan (f32 m/l stats)
    expert_axes_2d: bool = False    # experts sharded over (model, data): stationary weights
    ssm_pallas: bool = False        # fused Pallas SSD chunk scan (inference fwd)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    accum_dtype: str = "float32"
    # provenance
    source: str = ""                       # citation for the config

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_experts and self.dense_d_ff == 0:
            object.__setattr__(self, "dense_d_ff", self.d_ff)

    # ------------------------------------------------------------------ #
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for rooflines; exact for our impl)."""
        from repro.models.counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_active_params
        return count_active_params(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                              # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """Everything about the sync round, in one block (core/sync_engine.py).

    The *when* (policy), the *what* (wire codec), and the *how* (fused vs
    three-pass error-feedback encode) of the communication rounds that the
    paper's whole contribution is about making cheaper.
    """

    # --- schedule (core/sync_policy.py; local optimizers) ---
    # 'fixed_h'  -> the paper's every-H-steps schedule (bit-identical);
    # 'adaptive' -> CADA-style: sync once the accumulated drift statistic
    #               since the last sync crosses threshold, never before
    #               h_min local steps, always by h_max (0 -> 4·H).
    policy: str = "fixed_h"
    threshold: float = 0.0                 # accumulated drift trigger
    h_min: int = 1                         # adaptive lower bound on the period
    h_max: int = 0                         # adaptive upper bound; 0 -> 4·H
    # which divergence statistic the compiled steps emit for 'adaptive':
    # 'update_norm'     per-step relative parameter movement (cheap: reuses
    #                   arrays the update already touched);
    # 'grad_staleness'  CADA-proper ‖g_t − g_last_sync‖² (relative to ‖g_t‖²;
    #                   costs one extra param-sized anchor buffer per worker).
    drift_metric: str = "update_norm"
    # --- wire codec (core/codecs.py; local optimizers only) ---
    # ''/'fp32' -> fp32 payload (paper), 'bf16' -> 2x truncation,
    # 'int8' -> per-block int8 + fp32 scales (~4x less); lossy codecs get
    # error feedback from the engine's encode.
    compression: str = ""
    block: int = 256                       # elements per quantization block
    # one-HBM-pass fused EF+quantize+dequantize+residual kernel
    # (kernels/sync_fused.py) instead of the three-pass composition; bitwise
    # identical, so this is purely a bandwidth knob.
    fused: bool = True


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Paper algorithms 1-4 plus plain SGD.

    Everything about the sync round is consumed through the
    :class:`SyncConfig` view (``cfg.sync``) — one block for the policy, the
    wire codec, the drift metric and the fused-encode knob. The flat field
    names below are the storage (and the back-compat constructor/attribute
    aliases, so pre-SyncConfig call sites and ``dataclasses.replace`` work
    unchanged); :meth:`from_sync` constructs from an explicit block.
    """

    name: str = "local_adaalter"           # 'sgd'|'adagrad'|'adaalter'|'local_sgd'|'local_adaalter'
    lr: float = 0.5                        # paper default (8 workers x bs 256)
    eps: float = 1.0                       # paper: eps = 1
    b0: float = 1.0                        # paper: b0 = 1
    H: int = 4                             # paper's best comm/noise trade-off
    warmup_steps: int = 600                # paper: 600
    grad_clip: float = 0.0                 # global-norm clip; 0 -> off
    use_pallas: bool = False               # fused Pallas update kernel
    # flat parameter plane (core/flatspace.py): pack params + optimizer +
    # residual leaves into contiguous tile-aligned fp32 planes at init; the
    # AdaAlter step becomes ONE kernel launch over the whole plane and the
    # sync round ONE kernel + ONE collective instead of per-leaf ones.
    # Given the same sync schedule, the train STATE (params, accumulators,
    # wire, residuals) is bitwise identical to the per-leaf layout. Derived
    # scalars (loss, the adaptive policy's drift statistic) are reduction-
    # order-dependent and may differ in ulps between the two compiled
    # programs — so an ADAPTIVE schedule can diverge between layouts when
    # the accumulated drift lands within an ulp of the threshold; fixed_h
    # schedules are layout-independent. local_adaalter only.
    flat: bool = False
    # observability (obs/): compile the extra health metrics (raw-grad
    # global norm) into the step programs. Off by default so an
    # uninstrumented run pays literally nothing — the emission is not in
    # the jitted program at all, not merely skipped host-side. The train
    # CLI flips this on under --trace / --metrics.
    obs_metrics: bool = False
    # --- flat aliases of the SyncConfig block (read ``cfg.sync`` instead) ---
    sync_policy: str = "fixed_h"
    sync_threshold: float = 0.0
    h_min: int = 1
    h_max: int = 0
    drift_metric: str = "update_norm"
    compression: str = ""
    compression_block: int = 256
    sync_fused: bool = True

    #: SyncConfig field -> flat OptimizerConfig alias; the one table the
    #: sync property, from_sync and with_sync all iterate, so adding a
    #: field to SyncConfig means touching exactly this mapping (and the
    #: alias field above) once.
    _SYNC_ALIASES = {
        "policy": "sync_policy", "threshold": "sync_threshold",
        "h_min": "h_min", "h_max": "h_max", "drift_metric": "drift_metric",
        "compression": "compression", "block": "compression_block",
        "fused": "sync_fused",
    }

    def __post_init__(self):
        # --flat packs every leaf into zero-padded plane slots; the pads
        # only stay zero through the update because eps > 0 keeps
        # rsqrt(B² + t'·eps²) finite on them. eps == 0 would silently train
        # the pads on garbage, so refuse at construction time.
        if self.flat and self.eps <= 0:
            raise ValueError(
                "flat mode requires eps > 0: FlatSpace's zero slot padding "
                "survives the update only because rsqrt(B² + t'·eps²) stays "
                f"finite on zero pads (got eps={self.eps!r})")

    @property
    def sync(self) -> SyncConfig:
        """The sync-round configuration as one coherent block."""
        return SyncConfig(**{k: getattr(self, alias)
                             for k, alias in self._SYNC_ALIASES.items()})

    @classmethod
    def from_sync(cls, sync: SyncConfig, **kwargs) -> "OptimizerConfig":
        """Construct with an explicit :class:`SyncConfig` block; ``kwargs``
        are the non-sync fields (``name``, ``lr``, ``H``, ...)."""
        return cls(**{alias: getattr(sync, k)
                      for k, alias in cls._SYNC_ALIASES.items()}, **kwargs)

    def with_sync(self, sync: SyncConfig) -> "OptimizerConfig":
        """This config with its whole sync block swapped."""
        return dataclasses.replace(
            self, **{alias: getattr(sync, k)
                     for k, alias in self._SYNC_ALIASES.items()})


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    """How the mesh axes are used for a given (arch, shape).

    local_axes : mesh axes enumerating local-SGD workers (replicas diverge
                 between syncs; synced every H steps by Local AdaAlter).
    grad_axes  : mesh axes over which gradients are pmean'd EVERY step
                 (classic data parallelism inside a worker).
    fsdp_axes  : mesh axes over which each worker's params/optimizer state
                 are sharded (ZeRO-3); must be a subset of grad_axes.
    tp_axis    : tensor-parallel axis name.
    """

    local_axes: Tuple[str, ...] = ("data",)
    grad_axes: Tuple[str, ...] = ()
    fsdp_axes: Tuple[str, ...] = ()
    tp_axis: str = "model"
    weight_gather_serving: bool = False
    remat: str = "none"                    # 'none' | 'full' | 'dots'

    def n_workers(self, mesh) -> int:
        n = 1
        for ax in self.local_axes:
            n *= mesh.shape[ax]
        return n


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """End-to-end training run configuration."""

    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = OptimizerConfig()
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    eval_every: int = 0
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    non_iid: bool = True                   # paper assumption: D_i != D_j


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            max_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """A smoke-test-sized member of the same architecture family.

    (<=2 layers, d_model<=512, <=4 experts, small vocab) as required by the
    assignment; keeps every structural feature (GQA ratio, MoE, SSM, hybrid,
    enc-dec, cross-attn) intact so the smoke test exercises the same code path
    as the full config.
    """
    n_heads = max(4, min(cfg.n_heads, 8))
    # Preserve GQA grouping if the full config has it.
    n_kv = n_heads if cfg.n_kv_heads == cfg.n_heads else max(1, n_heads // 4)
    head_dim = max(16, d_model // n_heads)
    d_model = n_heads * head_dim
    changes = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=4 * d_model if cfg.d_ff else 0,
        dense_d_ff=4 * d_model if cfg.dense_d_ff else 0,
        vocab_size=vocab,
        n_experts=min(cfg.n_experts, max_experts),
        n_encoder_layers=n_layers if cfg.is_encdec else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        n_image_tokens=16 if cfg.cross_attn_every else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=16 if cfg.ssm_state else 64,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        lstm_proj=min(cfg.lstm_proj, 64) if cfg.lstm_proj else 0,
        moe_every=cfg.moe_every,
        name=cfg.name + "-smoke",
    )
    return dataclasses.replace(cfg, **changes)
