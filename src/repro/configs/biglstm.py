"""Big LSTM (LSTM-2048-512) — the paper's own evaluation model.

[Jozefowicz et al., arXiv:1602.02410, "LSTM-2048-512": 2-layer LSTM with
 2048 units projected to 512, word embeddings 512, vocab 793471 (1B-Word).
 Used by Local AdaAlter (arXiv:1911.09030) with 10% dropout.]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="biglstm",
    family="lstm",
    n_layers=2,
    d_model=2048,              # LSTM hidden units
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=793471,
    lstm_proj=512,             # recurrent projection + embedding size
    long_context_mode="ssm",   # O(1) recurrent decode state
    source="arXiv:1602.02410 via arXiv:1911.09030",
)
