"""Llama-4 Maverick 400B-A17B — MoE, 128 experts top-1, interleaved dense.

[hf:meta-llama/Llama-4-Scout-17B-16E family card; Maverick variant:
 128 routed experts, top-1 routing, shared expert, MoE every other layer,
 intermediate_size(expert)=8192, intermediate_size_mlp(dense/shared)=16384]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                 # per-expert FFN width
    dense_d_ff=16384,          # dense-layer / shared-expert FFN width
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    moe_every=2,               # MoE on every other layer (Maverick)
    shared_expert=True,
    rope_theta=500000.0,
    sliding_window=8192,       # used only in long_context_mode
    long_context_mode="sliding_window",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick 400B-A17B variant)",
)
