"""Phi-3.5-MoE 42B-A6.6B — 16 experts, top-2 routing.

[hf:microsoft/Phi-3.5-MoE-instruct; 32 layers, d_model=4096,
 32 heads / 8 kv heads, d_ff(expert)=6400, vocab=32064, 16e top-2]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    top_k=2,
    moe_every=1,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
