"""Llama-3 405B — dense decoder, GQA, 128k vocab.

[arXiv:2407.21783; 126 layers, d_model=16384, 128 heads / 8 kv heads,
 d_ff=53248, vocab=128256]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500000.0,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="arXiv:2407.21783",
)
