"""Mamba2-370m — attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060, Dao & Gu 2024; mamba2-370m: 48 layers, d_model=1024,
 d_state=128, expand=2, headdim=64, vocab=50280]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,                    # no separate FFN; the mamba block is the mixer
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=64,
    long_context_mode="ssm",   # O(1) decode state -> long_500k native
    source="arXiv:2405.21060",
)
