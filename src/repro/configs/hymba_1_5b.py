"""Hymba-1.5B — hybrid-head: parallel attention + mamba heads per layer.

[arXiv:2411.13676; 32 layers, d_model=1600, 25 heads / 5 kv heads
 (head_dim=64), d_ff=5504, vocab=32001, ssm_state=16; attention and SSM
 heads run in PARALLEL on the same input and are mean-fused.]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid=True,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=64,
    sliding_window=1024,       # Hymba uses SWA on most layers
    long_context_mode="ssm",   # SSM path carries long context natively
    source="arXiv:2411.13676",
)
