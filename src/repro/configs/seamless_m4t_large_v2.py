"""SeamlessM4T-large-v2 — encoder-decoder multimodal (audio) backbone.

[arXiv:2308.11596; transformer backbone only: 24 encoder + 24 decoder
 layers, d_model=1024, 16 heads (MHA: kv=16), d_ff=8192, vocab=256206.
 The speech frontend (mel + conformer conv) is STUBBED: input_specs()
 provides precomputed frame embeddings of shape (batch, frames, d_model).]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,               # decoder layers
    n_encoder_layers=24,       # encoder layers over stubbed frame embeddings
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    act="gelu",
    vocab_size=256206,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="arXiv:2308.11596",
)
