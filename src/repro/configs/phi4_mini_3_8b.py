"""Phi-4-mini 3.8B — dense decoder, RoPE + SwiGLU + GQA.

[arXiv:2412.08905; 32 layers, d_model=3072, 24 heads / 8 kv heads,
 d_ff=8192, vocab=200064]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="arXiv:2412.08905",
)
