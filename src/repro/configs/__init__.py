"""Architecture/shape config registry.

``get_arch(name)`` returns the full assigned config; ``get_shape(name)`` one
of the four assigned input shapes; ``reduced(cfg)`` a smoke-test variant.
"""
from repro.configs.base import (
    ModelConfig,
    OptimizerConfig,
    ParallelismPlan,
    ShapeConfig,
    TrainConfig,
    reduced,
)
from repro.configs.shapes import SHAPES, get_shape

from repro.configs import (  # noqa: E402  (registry imports)
    biglstm,
    hymba_1_5b,
    llama3_405b,
    llama4_maverick_400b_a17b,
    llama_3_2_vision_11b,
    mamba2_370m,
    minitron_4b,
    phi3_5_moe_42b_a6_6b,
    phi4_mini_3_8b,
    qwen2_7b,
    seamless_m4t_large_v2,
)

# The 10 assigned architectures (public-pool) + the paper's own model.
ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        llama4_maverick_400b_a17b,
        mamba2_370m,
        seamless_m4t_large_v2,
        qwen2_7b,
        llama3_405b,
        minitron_4b,
        phi4_mini_3_8b,
        llama_3_2_vision_11b,
        hymba_1_5b,
        phi3_5_moe_42b_a6_6b,
        biglstm,
    )
}

ASSIGNED = [n for n in ARCHS if n != "biglstm"]


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


__all__ = [
    "ARCHS",
    "ASSIGNED",
    "SHAPES",
    "ModelConfig",
    "OptimizerConfig",
    "ParallelismPlan",
    "ShapeConfig",
    "TrainConfig",
    "get_arch",
    "get_shape",
    "reduced",
]
