"""Llama-3.2-Vision 11B — decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; 40 layers, d_model=4096,
 32 heads / 8 kv heads, d_ff=14336, vocab=128256; cross-attn every 5th
 layer over vision tokens. The ViT/SigLIP frontend is STUBBED:
 input_specs() provides projected patch embeddings (batch, n_img, d_model).]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    n_image_tokens=1601,       # 1 tile x (40x40 patches + cls) as in the card
    rope_theta=500000.0,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
