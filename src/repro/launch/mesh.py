"""Production meshes and per-arch parallelism-plan resolution.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import os
import re
from typing import Optional

import jax

from repro.configs.base import ModelConfig, ParallelismPlan

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def require_host_devices(n: int, *, strict: bool = True) -> bool:
    """Ensure the host (CPU) platform exposes >= ``n`` simulated devices.

    Patches ``XLA_FLAGS`` (raising any existing
    ``--xla_force_host_platform_device_count`` to at least ``n``) — which
    only takes effect if the jax backend has NOT initialized yet — then
    verifies the live device count. Call it before any jax computation
    (dryrun does so at import time; multi-device tests run in a
    subprocess for the same reason). Returns True when ``n`` devices are
    available; with ``strict=False`` a too-late call degrades to False
    instead of raising, so opportunistic callers (benchmarks) can skip
    their multi-device sections.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(_HOST_COUNT_FLAG + r"=(\d+)", flags)
    if m is None:
        os.environ["XLA_FLAGS"] = f"{flags} {_HOST_COUNT_FLAG}={n}".strip()
    elif int(m.group(1)) < n:
        os.environ["XLA_FLAGS"] = flags.replace(
            m.group(0), f"{_HOST_COUNT_FLAG}={n}")
    if jax.device_count() >= n:
        return True
    if strict:
        raise RuntimeError(
            f"need {n} host devices but jax initialized with "
            f"{jax.device_count()} — require_host_devices must run before "
            "the first jax computation (use a subprocess if the parent "
            "already touched jax)")
    return False


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return jax.make_mesh(shape, axes)


def make_worker_shard_mesh(n_workers: int, n_shards: int = 1,
                           axes=("data", "model")):
    """2-D (workers × shards) CPU mesh for sharded ``--flat`` runs/tests.

    ``data`` carries the local-SGD workers, ``model`` the per-worker
    FSDP/TP plane shards (``sharding.partition.plane_shard_axes``). Sets
    the ``XLA_FLAGS`` host-device override when it can still take effect.
    """
    require_host_devices(n_workers * n_shards)
    return jax.make_mesh((n_workers, n_shards), axes)


# Parameter-count thresholds steering worker granularity (see DESIGN.md §2/§4)
_POD_WORKER_THRESHOLD = 20e9       # > 20B params: one local-SGD worker per pod
_SYNC_ONLY_THRESHOLD = 100e9       # > 100B: no local workers (AdaAlter, global FSDP)


def resolve_plan(cfg: ModelConfig, mesh, *, optimizer: str = "local_adaalter",
                 override: Optional[ParallelismPlan] = None) -> ParallelismPlan:
    """Choose local-SGD worker granularity from model size and mesh topology."""
    if override is not None:
        return override
    axes = set(mesh.shape.keys())
    has_pod = "pod" in axes
    n_params = cfg.param_count()
    local = optimizer in ("local_adaalter", "local_sgd")

    if n_params > _SYNC_ONLY_THRESHOLD or not local:
        # fully synchronous (AdaAlter/AdaGrad): all non-model axes do
        # data-parallel FSDP; the paper's "local" part is disabled.
        dp = tuple(a for a in ("pod", "data") if a in axes)
        return ParallelismPlan(local_axes=(), grad_axes=dp, fsdp_axes=dp,
                               remat="full" if n_params > 1e9 else "none",
                               weight_gather_serving=n_params > _POD_WORKER_THRESHOLD)
    if n_params > _POD_WORKER_THRESHOLD:
        # workers = pods; within a pod every-step sync + ZeRO over "data"
        return ParallelismPlan(
            local_axes=("pod",) if has_pod else (),
            grad_axes=("data",),
            fsdp_axes=("data",),
            remat="full",
            weight_gather_serving=True,
        )
    # paper-style many workers: every (pod, data) slice is a worker
    return ParallelismPlan(
        local_axes=("pod", "data") if has_pod else ("data",),
        grad_axes=(),
        fsdp_axes=(),
        remat="full" if n_params > 1e9 else "none",
    )
