"""Production meshes and per-arch parallelism-plan resolution.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig, ParallelismPlan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    return jax.make_mesh(shape, axes)


# Parameter-count thresholds steering worker granularity (see DESIGN.md §2/§4)
_POD_WORKER_THRESHOLD = 20e9       # > 20B params: one local-SGD worker per pod
_SYNC_ONLY_THRESHOLD = 100e9       # > 100B: no local workers (AdaAlter, global FSDP)


def resolve_plan(cfg: ModelConfig, mesh, *, optimizer: str = "local_adaalter",
                 override: Optional[ParallelismPlan] = None) -> ParallelismPlan:
    """Choose local-SGD worker granularity from model size and mesh topology."""
    if override is not None:
        return override
    axes = set(mesh.shape.keys())
    has_pod = "pod" in axes
    n_params = cfg.param_count()
    local = optimizer in ("local_adaalter", "local_sgd")

    if n_params > _SYNC_ONLY_THRESHOLD or not local:
        # fully synchronous (AdaAlter/AdaGrad): all non-model axes do
        # data-parallel FSDP; the paper's "local" part is disabled.
        dp = tuple(a for a in ("pod", "data") if a in axes)
        return ParallelismPlan(local_axes=(), grad_axes=dp, fsdp_axes=dp,
                               remat="full" if n_params > 1e9 else "none",
                               weight_gather_serving=n_params > _POD_WORKER_THRESHOLD)
    if n_params > _POD_WORKER_THRESHOLD:
        # workers = pods; within a pod every-step sync + ZeRO over "data"
        return ParallelismPlan(
            local_axes=("pod",) if has_pod else (),
            grad_axes=("data",),
            fsdp_axes=("data",),
            remat="full",
            weight_gather_serving=True,
        )
    # paper-style many workers: every (pod, data) slice is a worker
    return ParallelismPlan(
        local_axes=("pod", "data") if has_pod else ("data",),
        grad_axes=(),
        fsdp_axes=(),
        remat="full" if n_params > 1e9 else "none",
    )
