"""Batched serving driver: prefill a prompt batch, then greedy-decode tokens.

CPU-runnable with ``--reduced`` configs; the full-size configs are exercised
via the dry-run only.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, ShapeConfig, get_arch, reduced
from repro.data import SyntheticLM
from repro.launch.serving import build_serve_programs, serve_batch_specs


def serve_session(cfg, *, batch: int = 4, prompt_len: int = 32,
                  new_tokens: int = 16, seed: int = 0, mesh=None,
                  verbose: bool = True):
    """Returns (generated tokens (B, new_tokens), tokens/s)."""
    mesh = mesh or jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    cache_len = prompt_len + new_tokens
    shape = ShapeConfig(name="decode_32k", seq_len=cache_len,
                        global_batch=batch, kind="decode")
    with mesh:
        programs = build_serve_programs(cfg, shape, mesh)
        params = programs.init_fn(jax.random.PRNGKey(seed))
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                         n_workers=1, seed=seed)
        prompts = jnp.asarray(ds.worker_batch(0, 0, batch)["tokens"])

        # ---- prefill: run the prompt, then write its KV into a fresh cache
        pre_shape = ShapeConfig(name="prefill", seq_len=prompt_len,
                                global_batch=batch, kind="prefill")
        specs = serve_batch_specs(cfg, pre_shape)
        pre_batch = {"tokens": prompts}
        for k, v in specs["prefill"].items():
            if k != "tokens":
                pre_batch[k] = jnp.zeros(v.shape, v.dtype)
        logits, _ = programs.prefill(params, pre_batch)

        # decode continues from a zero cache replayed over the prompt —
        # simple and correct for every family (attention ring-buffer, SSM
        # recurrence, LSTM state all update via decode_step).
        from repro.launch.serving import decode_cache_specs
        cache = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, l.dtype),
            decode_cache_specs(cfg, shape))
        tok = prompts[:, :1]
        out = []
        t0 = time.time()
        for pos in range(cache_len - 1):
            if pos + 1 < prompt_len:
                nxt = prompts[:, pos + 1:pos + 2]            # teacher-forced
            else:
                nxt = None
            logits, cache = programs.decode_step(
                params, cache, tok.astype(jnp.int32),
                jnp.full((batch,), pos, jnp.int32))
            if nxt is None:
                nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                out.append(np.asarray(nxt))
            tok = nxt
            if len(out) >= new_tokens:
                break
        dt = time.time() - t0
        gen = np.concatenate(out, axis=1) if out else np.zeros((batch, 0), np.int32)
        tps = batch * gen.shape[1] / max(dt, 1e-9)
        if verbose:
            print(f"generated {gen.shape} tokens in {dt:.2f}s "
                  f"({tps:.1f} tok/s incl. prompt replay)")
        return gen, tps


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-7b", help=f"one of {sorted(ARCHS)}")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    gen, tps = serve_session(cfg, batch=args.batch, prompt_len=args.prompt_len,
                             new_tokens=args.new_tokens, seed=args.seed)
    print("sample generations (token ids):")
    for row in gen[:4]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
