"""End-to-end training driver (CPU-runnable, mesh-agnostic).

Trains any architecture config (typically a ``--reduced`` variant on CPU)
with any of the paper's optimizers on the synthetic non-IID LM stream,
logging loss/PPL and the communication volume each algorithm actually moved.

The whole sync round is owned by one ``SyncEngine``
(``core/sync_engine.py``) composing the schedule, the wire format, and the
device-side encode: ``--sync-policy fixed_h`` is the paper's every-H-steps
schedule (bit-identical to the historical modulo loop, including across
checkpoint restores), ``--sync-policy adaptive`` triggers the sync round on
the accumulated divergence statistic the compiled steps emit (CADA-style,
``--drift-metric update_norm|grad_staleness``), bounded by
``--h-min``/``--h-max``. ``--compress bf16`` halves the payload,
``--compress int8`` shrinks it ~4x with error feedback — fused into a
single-HBM-pass Pallas kernel unless ``--unfused-sync``. Checkpoints carry
the engine's ``SyncState`` (drift accumulator + window position) next to
``(params, opt_state)``, so a mid-window restore resumes the exact adaptive
schedule. ``TrainResult`` reports the *measured* sync count/steps and the
comm bytes they moved, not the static ``2P/H`` formula. ``--trace out.json``
additionally records the run as a per-worker span timeline (``repro.trace``)
— the engine's actual sync decisions plus modeled device/wire round costs —
for Perfetto viewing and trace-driven what-if replay. ``--metrics out.jsonl``
streams per-step sync-health metrics (``repro.obs``: grad norm, drift, B²
quantiles, EF residual norms, int8 quantization MSE, wire compression
ratio) as JSONL plus a Prometheus textfile snapshot.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --optimizer local_adaalter --H 4 --steps 200 --batch 16 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch biglstm --reduced \
      --optimizer local_adaalter --sync-policy adaptive --sync-threshold \
      0.05 --h-min 2 --h-max 16 --compress bf16 --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCHS, OptimizerConfig, ShapeConfig, get_arch,
                           get_shape, reduced)
from repro.configs.base import ModelConfig, ParallelismPlan, TrainConfig
from repro.core import comm
from repro.core.codecs import CODEC_NAMES
from repro.core.sync_engine import DRIFT_METRICS, make_sync_engine
from repro.core.sync_policy import POLICY_NAMES
from repro.data import SyntheticLM, make_train_batch
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs
from repro.models.counting import count_params


def make_cpu_mesh(n_workers: Optional[int] = None):
    """(data, model) mesh over the host devices.

    ``n_workers`` sizes the data (worker) axis; remaining devices go to the
    model axis. Default (None) keeps the old behaviour: all devices on the
    data axis. Requests that don't divide the device count fall back to that
    default instead of silently being ignored (the old bug).
    """
    n = jax.device_count()
    data = n if n_workers is None else max(1, min(n_workers, n))
    if n % data:
        data = n
    return jax.make_mesh((data, n // data), ("data", "model"))


@dataclasses.dataclass
class TrainResult:
    losses: List[float]                    # this run only (post-restore)
    ppl: List[float]
    steps: int                             # steps executed THIS run
    n_workers: int
    comm_bytes_per_step: float             # MEASURED: moved bytes / steps run
    wall_s: float
    final_loss: float
    start_step: int = 0                    # checkpoint-restore point (0 = fresh)
    sync_count: int = 0                    # sync rounds the policy triggered
    sync_steps: List[int] = dataclasses.field(default_factory=list)
    comm_bytes_total: float = 0.0          # measured wire bytes, WHOLE run
    comm_bytes_modeled: float = 0.0        # static fixed-H formula, PER STEP
                                           # (compare with comm_bytes_per_step,
                                           # not comm_bytes_total)
    sync_policy: str = "fixed_h"


def train_loop(cfg: ModelConfig, shape: ShapeConfig, opt_cfg: OptimizerConfig,
               *, steps: int = 100, seed: int = 0, log_every: int = 10,
               mesh=None, plan: Optional[ParallelismPlan] = None,
               non_iid: bool = True, checkpoint_dir: str = "",
               checkpoint_every: int = 0, verbose: bool = True,
               trace_out: str = "", metrics_out: str = "") -> TrainResult:
    """``trace_out`` records the run as a span stream (``repro.trace``):
    one timeline row per worker per step carrying the sync decisions the
    engine actually took, plus modeled device/wire costs on sync rounds —
    the input of the what-if replay engine and the Chrome/Perfetto export.
    All host times (including ``wall_s``) share the monotonic
    ``time.perf_counter`` clock.

    ``metrics_out`` streams the run's health metrics (``repro.obs``): one
    JSONL row per step — loss, grad norm, drift, B² quantiles per dtype
    bucket, and on sync rounds the EF residual norms and quantization MSE —
    plus a Prometheus textfile snapshot next to it (``<base>.prom``).
    Both instrumentations share one ``SyncHealthProbe``, so the trace spans
    and the metrics rows report the same numbers."""
    if trace_out or metrics_out:
        # compile the grad-norm health metric into the step programs; an
        # uninstrumented run's programs stay byte-identical (the emission
        # is absent, not skipped)
        opt_cfg = dataclasses.replace(opt_cfg, obs_metrics=True)
    mesh = mesh or make_cpu_mesh()
    plan = plan or resolve_plan(cfg, mesh, optimizer=opt_cfg.name)
    with mesh:
        programs = build_train_programs(cfg, shape, opt_cfg, mesh, plan)
        R = programs.n_workers if programs.is_local else 1
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                         n_workers=max(R, 1), seed=seed, non_iid=non_iid)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(seed))

        # The whole sync round is the engine's: the host-side schedule
        # (fixed_h reproduces the historical `(step+1) % H` modulo
        # bit-identically), the wire codec, the fused device-side encode
        # the jitted sync_step already contains, and the checkpointable
        # SyncState the adaptive schedule resumes from.
        engine = make_sync_engine(opt_cfg, is_local=programs.is_local,
                                  H=programs.H if programs.is_local else 1)
        start_step = 0
        sync_state = None
        if checkpoint_dir:
            from repro.checkpoint import (checkpoint_keys, latest_step,
                                          restore_checkpoint)
            from repro.core.flatspace import is_flat_checkpoint
            if latest_step(checkpoint_dir) is not None:
                keys = checkpoint_keys(checkpoint_dir)
                # Pre-SyncState checkpoints are (params, opt_state)
                # 2-tuples; pick the template matching the on-disk manifest
                # so the adaptive window just re-anchors for those, while a
                # genuinely mismatched checkpoint (different arch/worker
                # count) still fails with its real shape/key error.
                no_ss = not any(k.startswith("#2/") for k in keys)
                # A checkpoint written under either parameter layout
                # restores into either mode: the manifest says which layout
                # is on disk (packed planes vs per-leaf pytrees), and the
                # programs' FlatSpace adapters convert after the restore.
                disk_flat = is_flat_checkpoint(keys)
                if disk_flat == programs.is_flat:
                    abstract = jax.eval_shape(lambda: (params, opt_state))
                elif disk_flat:
                    if programs.flat_abstract is None:
                        raise ValueError(
                            "checkpoint holds a flat parameter plane but "
                            "this run has no FlatSpace (flat layout is "
                            "local Local AdaAlter only)")
                    abstract = programs.flat_abstract
                else:
                    abstract = programs.legacy_abstract
                like = (abstract if no_ss
                        else (*abstract, engine.export_state()))
                resharded = False
                if disk_flat:
                    # Cross-MESH flat restore: a plane written under a
                    # different (workers × shards) mesh carries different
                    # plane/counter shapes. Restore into the on-disk shapes,
                    # then reshard host-side (tail-pad-only slot layout: pad/
                    # truncate the zero tail, replicate or merge worker rows).
                    from repro.checkpoint import disk_like
                    like = disk_like(checkpoint_dir, like)
                state, start_step = restore_checkpoint(checkpoint_dir, like)
                if no_ss:
                    params, opt_state = state
                else:
                    params, opt_state, sync_state = state
                if disk_flat:
                    from repro.core.flatspace import adapt_flat_state
                    want = (programs.n_workers,
                            programs.flatspace.plane_size)
                    if tuple(params.shape) != want:
                        disk_shape = tuple(params.shape)
                        params, opt_state = adapt_flat_state(
                            params, opt_state, workers=want[0],
                            plane_size=want[1])
                        resharded = True
                if disk_flat and not programs.is_flat:
                    params, opt_state = programs.to_legacy(params, opt_state)
                elif programs.is_flat and not disk_flat:
                    params, opt_state = programs.to_flat(params, opt_state)
                if verbose:
                    layout = ""
                    if disk_flat != programs.is_flat:
                        layout = (" (flat -> per-leaf)" if disk_flat
                                  else " (per-leaf -> flat)")
                    if resharded:
                        layout += (f" (resharded plane {disk_shape} -> "
                                   f"{want})")
                    print(f"restored checkpoint at step {start_step}"
                          f"{' (no SyncState)' if no_ss else ''}{layout}")
        engine.reset(start_step)
        if sync_state is not None:
            engine.import_state(sync_state)
        n_params = count_params(cfg)

        # ---- obs: metrics registry + the shared sync-health probe --------- #
        from repro.obs import NULL_REGISTRY, SyncHealthProbe
        registry = NULL_REGISTRY
        if metrics_out:
            from repro.obs import MetricsRegistry
            registry = MetricsRegistry(labels={
                "arch": cfg.name, "algorithm": opt_cfg.name,
                "policy": opt_cfg.sync.policy,
                "codec": opt_cfg.sync.compression or "fp32", "workers": R})
            registry.open_jsonl(metrics_out)
        probe = None
        if registry or trace_out:
            probe = SyncHealthProbe.build(engine, programs, n_params)
            if registry:
                registry.set_many(probe.static_summary())

        # ---- trace recorder (repro.trace): spans + modeled round costs ---- #
        recorder = None
        if trace_out:
            from repro.roofline import V5E
            from repro.trace import TraceRecorder
            n_coll = engine.round_collectives(programs.n_payload_leaves,
                                              flat=programs.is_flat)
            round_b = engine.round_bytes(n_params)
            # modeled device-side encode + wire time of ONE sync round —
            # attached to every round's ef_encode/collective spans (a CPU
            # host cannot measure the TPU-side pass or a real fabric)
            enc_bytes = engine.modeled_encode_hbm_bytes(n_params)
            enc_t = enc_bytes / V5E.hbm_bw
            # with a sharded flat plane each device's worker-axis collective
            # moves its sub-plane only — the replay engine prices the round
            # per shard, not full-plane
            shard_b = engine.round_bytes_per_shard(n_params,
                                                   programs.n_shards)
            wire_t = comm.collective_time(shard_b, n_coll, R)
            st0 = engine.export_state()
            recorder = TraceRecorder(meta={
                "kind": "train", "arch": cfg.name,
                "algorithm": opt_cfg.name, "n_params": int(n_params),
                "n_workers": R, "steps": steps, "start_step": start_step,
                "H": programs.H, "is_local": programs.is_local,
                "flat": programs.is_flat,
                "sync": dataclasses.asdict(opt_cfg.sync),
                "use_pallas": opt_cfg.use_pallas,
                "n_payload_leaves": programs.n_payload_leaves,
                "n_collectives_per_round": n_coll,
                "n_shards": programs.n_shards,
                "round_wire_bytes_per_shard": shard_b,
                "fabric": dataclasses.asdict(comm.FabricModel()),
                "hbm_bw": V5E.hbm_bw, "clock": "perf_counter",
                "sync_state0": {"since": int(st0.since),
                                "drift": float(st0.drift)},
            })

        # ---- HLO per-op cost attribution (roofline.region_table) --------- #
        # AOT-lower both step programs and walk their optimized HLO into a
        # per-fused-region flops/bytes/optimal-seconds table. The replay
        # engine prices sync overhead from the sync/local optimal ratio
        # (deterministic program structure, not a noisy difference of two
        # measured means), and every local_step span carries the roofline-
        # optimal wall of its program. Costs one extra compile per program
        # (the AOT cache is separate from the loop's jit cache) — accepted
        # under opt-in tracing; any lowering failure degrades to a trace
        # without hlo_cost meta, which replay prices from warm means.
        hlo_local_s = hlo_extra_s = None
        if recorder is not None:
            try:
                from repro.roofline import region_table
                bnp = make_train_batch(cfg, shape, ds, start_step,
                                       n_workers=R if programs.is_local
                                       else 0)
                b0 = jax.tree_util.tree_map(jnp.asarray, bnp)
                tabs = {}
                for prog_key, prog_fn in (("local_step", programs.local_step),
                                          ("sync_step", programs.sync_step)):
                    txt = prog_fn.lower(params, opt_state,
                                        b0).compile().as_text()
                    tabs[prog_key] = region_table(
                        txt, peak_flops=V5E.peak_flops, hbm_bw=V5E.hbm_bw)
                recorder.meta["hlo_cost"] = {
                    **tabs, "hw": {"peak_flops": V5E.peak_flops,
                                   "hbm_bw": V5E.hbm_bw}}
                hlo_local_s = float(tabs["local_step"]["optimal_s"])
                hlo_extra_s = max(0.0, float(tabs["sync_step"]["optimal_s"])
                                  - hlo_local_s)
            except Exception as e:               # pragma: no cover - backend
                if verbose:
                    print(f"HLO cost attribution unavailable: {e}")

        losses, ppls = [], []
        t0 = time.perf_counter()
        for step in range(start_step, steps):
            batch_np = make_train_batch(cfg, shape, ds, step,
                                        n_workers=R if programs.is_local else 0)
            batch = jax.tree_util.tree_map(jnp.asarray, batch_np)
            do_sync = engine.want_sync(step)
            t_step = (recorder.now() if recorder is not None
                      else time.perf_counter() if registry else 0.0)
            fn = programs.sync_step if do_sync else programs.local_step
            params, opt_state, metrics = fn(params, opt_state, batch)
            # the blocking metric read keeps the device work inside the span
            loss = float(metrics["loss"])
            drift_val = (float(metrics.get("drift", 0.0))
                         if engine.wants_drift else 0.0)
            # decision-time window state (before observe folds this step in)
            st = engine.export_state() if recorder is not None else None
            engine.observe(step, do_sync,
                           {"drift": drift_val}
                           if engine.wants_drift else None)
            # ONE health summary feeds both exports (same numbers on the
            # trace spans and in the metrics rows, by construction)
            summary = (probe.step_summary(opt_state, metrics,
                                          synced=do_sync)
                       if probe is not None else {})
            if recorder is not None:
                from repro.trace.events import health_span_args
                dur = recorder.now() - t_step
                t_end = t_step + dur
                health = health_span_args(summary)
                if hlo_local_s is not None:
                    health["hlo_optimal_s"] = hlo_local_s
                for w in range(R):
                    recorder.add("local_step", worker=w, step=step,
                                 t0=t_step, dur=dur, synced=do_sync,
                                 loss=loss, drift=drift_val,
                                 sync_since=int(st.since),
                                 sync_drift=float(st.drift), **health)
                    if do_sync:
                        enc_args = {}
                        if hlo_extra_s is not None:
                            enc_args["hlo_extra_optimal_s"] = hlo_extra_s
                        recorder.add("ef_encode", worker=w, step=step,
                                     t0=t_end, dur=enc_t, modeled=True,
                                     hbm_bytes=enc_bytes,
                                     codec=engine.codec.name, **enc_args)
                        recorder.add("collective", worker=w, step=step,
                                     t0=t_end + enc_t, dur=wire_t,
                                     modeled=True, wire_bytes=round_b,
                                     wire_bytes_per_shard=shard_b,
                                     n_shards=programs.n_shards,
                                     n_collectives=n_coll,
                                     codec=engine.codec.name, workers=R)
            if registry:
                step_dur = (dur if recorder is not None
                            else time.perf_counter() - t_step)
                registry.counter("steps_total").inc()
                registry.gauge("loss",
                               help="train loss (mean over workers)"
                               ).set(loss)
                registry.histogram("step_time_s",
                                   help="host wall of one train step"
                                   ).observe(step_dur)
                probe.record(registry, summary, step=step, synced=do_sync)
                registry.collect(step)
            losses.append(loss)
            ppls.append(math.exp(min(loss, 30.0)))
            if verbose and (step % log_every == 0 or step == steps - 1):
                t_ev = recorder.now() if recorder is not None else 0.0
                print(f"step {step:5d} loss {loss:8.4f} ppl {ppls[-1]:10.2f} "
                      f"{'sync' if do_sync else 'local'}")
                if recorder is not None:
                    recorder.add("eval", step=step, t0=t_ev,
                                 dur=recorder.now() - t_ev, loss=loss)
            if checkpoint_dir and checkpoint_every and \
                    (step + 1) % checkpoint_every == 0:
                from repro.checkpoint import save_checkpoint
                t_ck = recorder.now() if recorder is not None else 0.0
                save_checkpoint(checkpoint_dir, step + 1,
                                (params, opt_state, engine.export_state()))
                if recorder is not None:
                    recorder.add("ckpt", step=step, t0=t_ck,
                                 dur=recorder.now() - t_ck,
                                 dir=checkpoint_dir)

        wall = time.perf_counter() - t0
        executed = max(steps - start_step, 0)
        # Measured comm: what the schedule that actually ran moved — the
        # engine's sync count times its per-round codec payload (for local
        # optimizers; synchronous ones all-reduce a gradient every step).
        # The static 2P/H formula is kept alongside as `comm_bytes_modeled`;
        # the two diverge under the adaptive policy and after a restore into
        # the middle of an H-window.
        if programs.is_local:
            total = engine.sync_count * engine.round_bytes(n_params)
            modeled = engine.modeled_bytes_per_step(n_params)
        else:
            # Synchronous execution (incl. a LocalOptimizer forced onto a
            # sync-only plan, where `sync` runs every step with an identity
            # mean): the only wire traffic is GSPMD's per-step gradient
            # all-reduce — P bytes, untouched by H or the sync codec — so
            # both numbers report that, not the inapplicable 2P/H formula.
            total = executed * engine.grad_allreduce_bytes(n_params)
            modeled = engine.grad_allreduce_bytes(n_params)
        # After a restore only the post-restore losses exist: report the
        # steps actually executed and guard the empty-run case (restore at or
        # past the target used to yield steps=target and a NaN-mean warning).
        final = float(np.mean(losses[-10:])) if losses else float("nan")
        if registry:
            registry.gauge("final_loss",
                           help="mean loss over the last 10 steps").set(final)
            base = (metrics_out[:-len(".jsonl")]
                    if metrics_out.endswith(".jsonl") else metrics_out)
            registry.write_prom(base + ".prom")
            registry.close()
            if verbose:
                print(f"wrote metrics {metrics_out} "
                      f"(+ Prometheus textfile {base + '.prom'})")
        if recorder is not None:
            recorder.meta["measured"] = {
                "wall_s": wall, "sync_count": engine.sync_count,
                "sync_steps": list(engine.sync_steps), "final_loss": final}
            recorder.save(trace_out)
            if verbose:
                print(f"wrote trace {trace_out} ({len(recorder.spans)} "
                      f"spans; python -m repro.trace.chrome {trace_out} "
                      f"to view, python -m repro.trace.replay for what-ifs)")
        return TrainResult(losses=losses, ppl=ppls, steps=executed,
                           n_workers=R,
                           comm_bytes_per_step=total / executed if executed
                           else 0.0,
                           wall_s=wall, final_loss=final,
                           start_step=start_step,
                           sync_count=engine.sync_count,
                           sync_steps=list(engine.sync_steps),
                           comm_bytes_total=total,
                           comm_bytes_modeled=modeled,
                           sync_policy=engine.name)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="biglstm", help=f"one of {sorted(ARCHS)}")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized family member (CPU-friendly)")
    ap.add_argument("--optimizer", default="local_adaalter",
                    choices=["sgd", "adagrad", "adaalter", "local_sgd",
                             "local_adaalter"])
    ap.add_argument("--H", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", nargs="?", const="int8", default="",
                    choices=["", *CODEC_NAMES], metavar="SCHEME",
                    help="sync wire codec (local optimizers): 'bf16' halves "
                         "the payload, 'int8' shrinks it ~4x (per-block "
                         "int8 + fp32 scales); both get error feedback. "
                         "Bare --compress means int8")
    ap.add_argument("--sync-policy", default="fixed_h", choices=POLICY_NAMES,
                    help="'fixed_h': the paper's every-H-steps schedule; "
                         "'adaptive': CADA-style — sync when the accumulated "
                         "parameter drift since the last sync crosses "
                         "--sync-threshold, no sooner than --h-min steps, "
                         "no later than --h-max")
    ap.add_argument("--sync-threshold", type=float, default=0.05,
                    help="adaptive trigger on the accumulated drift "
                         "statistic (metrics['drift'])")
    ap.add_argument("--drift-metric", default="update_norm",
                    choices=DRIFT_METRICS,
                    help="which drift statistic feeds the adaptive policy: "
                         "'update_norm' (relative per-step parameter "
                         "movement) or 'grad_staleness' (CADA-proper "
                         "relative ||g_t - g_last_sync||^2)")
    ap.add_argument("--h-min", type=int, default=1,
                    help="adaptive: minimum local steps between syncs")
    ap.add_argument("--h-max", type=int, default=0,
                    help="adaptive: maximum local steps between syncs "
                         "(0 -> 4*H)")
    ap.add_argument("--unfused-sync", action="store_true",
                    help="compose the sync encode from three HBM passes "
                         "(EF add / quantize / dequantize+residual) instead "
                         "of the fused one-pass kernel — bitwise identical; "
                         "bench/debug knob")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route the fused AdaAlter update and the sync "
                         "codec through the Pallas kernels (interpret mode "
                         "off-TPU, Mosaic on TPU)")
    ap.add_argument("--flat", action="store_true",
                    help="flat parameter plane (core/flatspace.py): pack "
                         "params + optimizer state into contiguous planes "
                         "at init; the AdaAlter step becomes ONE kernel "
                         "launch and the sync round ONE kernel + ONE "
                         "collective instead of per-leaf ones. Train state "
                         "is bitwise identical to the per-leaf layout under "
                         "the same schedule (adaptive drift scalars, like "
                         "loss, may differ in ulps and shift a threshold-"
                         "edge sync); checkpoints restore across both "
                         "layouts")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record the run as a span timeline (repro.trace): "
                         "per-worker per-step spans with the engine's sync "
                         "decisions + modeled device/wire costs. Export "
                         "with `python -m repro.trace.chrome`, what-if "
                         "replay with `python -m repro.trace.replay`")
    ap.add_argument("--metrics", default="", metavar="OUT.jsonl",
                    help="stream per-step health metrics (repro.obs): one "
                         "JSONL row per step — loss, raw-grad norm, drift, "
                         "B² quantiles per dtype bucket, EF residual norms "
                         "and quantization MSE on sync rounds, wire "
                         "compression ratio — plus a Prometheus textfile "
                         "snapshot next to it (OUT.prom)")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="size of the mesh's data (worker) axis; remaining "
                         "host devices form the model axis, which a --flat "
                         "run uses to FSDP/TP-shard each worker's plane "
                         "(sharded sub-planes, per-shard sync payload). "
                         "0 -> all devices on the worker axis. Pair with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=K "
                         "to simulate K CPU devices")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--iid", action="store_true", help="disable non-IID workers")
    ap.add_argument("--out", default="", help="write metrics JSON here")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, vocab=args.vocab)
    shape = ShapeConfig(name="cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    from repro.configs.base import SyncConfig
    opt_cfg = OptimizerConfig.from_sync(
        SyncConfig(policy=args.sync_policy, threshold=args.sync_threshold,
                   h_min=args.h_min, h_max=args.h_max,
                   drift_metric=args.drift_metric,
                   compression=args.compress,
                   fused=not args.unfused_sync),
        name=args.optimizer, lr=args.lr, H=args.H,
        warmup_steps=args.warmup, use_pallas=args.use_pallas,
        flat=args.flat)
    sched = (f"H={args.H}" if args.sync_policy == "fixed_h" else
             f"adaptive(thr={args.sync_threshold}, "
             f"h=[{args.h_min},{args.h_max or 4 * args.H}])")
    mesh = make_cpu_mesh(args.workers or None)
    print(f"training {cfg.name} ({count_params(cfg):,} params) with "
          f"{args.optimizer} {sched}"
          f"{' +' + args.compress + ' sync' if args.compress else ''} "
          f"on {jax.device_count()} device(s), mesh "
          f"{dict(mesh.shape)}")
    res = train_loop(cfg, shape, opt_cfg, steps=args.steps, seed=args.seed,
                     mesh=mesh, non_iid=not args.iid,
                     checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=args.checkpoint_every,
                     trace_out=args.trace, metrics_out=args.metrics)
    print(f"done in {res.wall_s:.1f}s; final loss {res.final_loss:.4f}; "
          f"{res.sync_count} syncs in {res.steps} steps; measured comm/step "
          f"{res.comm_bytes_per_step / 1e6:.1f} MB (modeled "
          f"{res.comm_bytes_modeled / 1e6:.1f} MB; {res.n_workers} workers)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(dataclasses.asdict(res), f, indent=1)


if __name__ == "__main__":
    main()
