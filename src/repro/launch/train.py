"""End-to-end training driver (CPU-runnable, mesh-agnostic).

Trains any architecture config (typically a ``--reduced`` variant on CPU)
with any of the paper's optimizers on the synthetic non-IID LM stream,
logging loss/PPL and the communication volume each algorithm would move.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --optimizer local_adaalter --H 4 --steps 200 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCHS, OptimizerConfig, ShapeConfig, get_arch,
                           get_shape, reduced)
from repro.configs.base import ModelConfig, ParallelismPlan, TrainConfig
from repro.core.comm import sync_bytes_per_step
from repro.data import SyntheticLM, make_train_batch
from repro.launch.mesh import resolve_plan
from repro.launch.steps import build_train_programs
from repro.models.counting import count_params


def make_cpu_mesh(n_workers: Optional[int] = None):
    """(data, model) mesh over the host devices.

    ``n_workers`` sizes the data (worker) axis; remaining devices go to the
    model axis. Default (None) keeps the old behaviour: all devices on the
    data axis. Requests that don't divide the device count fall back to that
    default instead of silently being ignored (the old bug).
    """
    n = jax.device_count()
    data = n if n_workers is None else max(1, min(n_workers, n))
    if n % data:
        data = n
    return jax.make_mesh((data, n // data), ("data", "model"))


@dataclasses.dataclass
class TrainResult:
    losses: List[float]                    # this run only (post-restore)
    ppl: List[float]
    steps: int                             # steps executed THIS run
    n_workers: int
    comm_bytes_per_step: float
    wall_s: float
    final_loss: float
    start_step: int = 0                    # checkpoint-restore point (0 = fresh)


def train_loop(cfg: ModelConfig, shape: ShapeConfig, opt_cfg: OptimizerConfig,
               *, steps: int = 100, seed: int = 0, log_every: int = 10,
               mesh=None, plan: Optional[ParallelismPlan] = None,
               non_iid: bool = True, checkpoint_dir: str = "",
               checkpoint_every: int = 0, verbose: bool = True) -> TrainResult:
    mesh = mesh or make_cpu_mesh()
    plan = plan or resolve_plan(cfg, mesh, optimizer=opt_cfg.name)
    with mesh:
        programs = build_train_programs(cfg, shape, opt_cfg, mesh, plan)
        R = programs.n_workers if programs.is_local else 1
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                         n_workers=max(R, 1), seed=seed, non_iid=non_iid)
        params, opt_state = programs.init_fn(jax.random.PRNGKey(seed))

        start_step = 0
        if checkpoint_dir:
            from repro.checkpoint import latest_step, restore_checkpoint
            if latest_step(checkpoint_dir) is not None:
                state, start_step = restore_checkpoint(
                    checkpoint_dir, jax.eval_shape(lambda: (params, opt_state)))
                params, opt_state = state
                if verbose:
                    print(f"restored checkpoint at step {start_step}")

        H = programs.H if programs.is_local else 1
        losses, ppls = [], []
        t0 = time.time()
        for step in range(start_step, steps):
            batch_np = make_train_batch(cfg, shape, ds, step,
                                        n_workers=R if programs.is_local else 0)
            batch = jax.tree_util.tree_map(jnp.asarray, batch_np)
            do_sync = ((step + 1) % H == 0)
            fn = programs.sync_step if do_sync else programs.local_step
            params, opt_state, metrics = fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            ppls.append(math.exp(min(loss, 30.0)))
            if verbose and (step % log_every == 0 or step == steps - 1):
                print(f"step {step:5d} loss {loss:8.4f} ppl {ppls[-1]:10.2f} "
                      f"{'sync' if do_sync else 'local'}")
            if checkpoint_dir and checkpoint_every and \
                    (step + 1) % checkpoint_every == 0:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(checkpoint_dir, step + 1, (params, opt_state))

        wall = time.time() - t0
        n_params = count_params(cfg)
        comm = sync_bytes_per_step(opt_cfg.name, n_params, opt_cfg.H,
                                   compression=opt_cfg.compression,
                                   block=opt_cfg.compression_block)
        # After a restore only the post-restore losses exist: report the
        # steps actually executed and guard the empty-run case (restore at or
        # past the target used to yield steps=target and a NaN-mean warning).
        final = float(np.mean(losses[-10:])) if losses else float("nan")
        return TrainResult(losses=losses, ppl=ppls,
                           steps=max(steps - start_step, 0),
                           n_workers=R, comm_bytes_per_step=comm,
                           wall_s=wall, final_loss=final,
                           start_step=start_step)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="biglstm", help=f"one of {sorted(ARCHS)}")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized family member (CPU-friendly)")
    ap.add_argument("--optimizer", default="local_adaalter",
                    choices=["sgd", "adagrad", "adaalter", "local_sgd",
                             "local_adaalter"])
    ap.add_argument("--H", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", nargs="?", const="int8", default="",
                    choices=["", "int8"], metavar="SCHEME",
                    help="quantize the sync payload (local optimizers); "
                         "bare --compress means int8 + error feedback")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--iid", action="store_true", help="disable non-IID workers")
    ap.add_argument("--out", default="", help="write metrics JSON here")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, vocab=args.vocab)
    shape = ShapeConfig(name="cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    opt_cfg = OptimizerConfig(name=args.optimizer, lr=args.lr, H=args.H,
                              warmup_steps=args.warmup,
                              compression=args.compress)
    print(f"training {cfg.name} ({count_params(cfg):,} params) with "
          f"{args.optimizer} H={args.H}"
          f"{' +' + args.compress + ' sync' if args.compress else ''} "
          f"on {jax.device_count()} device(s)")
    res = train_loop(cfg, shape, opt_cfg, steps=args.steps, seed=args.seed,
                     non_iid=not args.iid, checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=args.checkpoint_every)
    print(f"done in {res.wall_s:.1f}s; final loss {res.final_loss:.4f}; "
          f"avg comm/step {res.comm_bytes_per_step / 1e6:.1f} MB "
          f"({res.n_workers} workers)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(dataclasses.asdict(res), f, indent=1)


if __name__ == "__main__":
    main()
