from repro.launch.mesh import require_host_devices
require_host_devices(512)
# The two lines above MUST run before any jax computation: jax locks the
# device count at first initialization, and the production dry-run needs 512
# placeholder host devices to build the 16x16 (single-pod) and 2x16x16
# (multi-pod) meshes. Everything else (tests, benches) sees 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

For each pair this proves the sharding config is coherent (no GSPMD
mismatch, no unsupported collective) and extracts the roofline inputs:
``compiled.memory_analysis()`` (fits-in-HBM proof) and
``compiled.cost_analysis()`` + HLO collective bytes (§Roofline terms).

  train_4k    lowers train_step (both the local/comm-free variant and the
              H-th sync variant when the optimizer is local);
  prefill_32k lowers serve prefill;
  decode_32k / long_500k lower serve_step: ONE token against the KV cache.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh single \
      --out experiments/dryrun
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh multi
"""
import argparse
import dataclasses
import json
import os
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCHS, ASSIGNED, SHAPES, OptimizerConfig, get_arch, get_shape
from repro.launch.mesh import make_production_mesh, resolve_plan
from repro.launch.serving import (build_serve_programs, decode_cache_specs,
                                  serve_batch_specs, serve_plan)
from repro.launch.steps import build_train_programs, train_batch_specs
from repro.roofline import analyze, model_flops


def _mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.shape)


def _abstract(tree):
    """Strip shardings: plain ShapeDtypeStructs for .lower()."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


OPT_FLAGS = dict(attn_tp_pad=True, attn_remat=True, fused_xent=True,
                 moe_group_tokens=True, seq_parallel=True)
# expert_axes_2d: REFUTED (§Perf llama4 iter 2): GSPMD gathers the global
# token table instead of all-to-all -> collective 31s -> 67s.
# attn_bf16_probs: REFUTED under CPU f32-promoted lowering (§Perf qwen iter 5)


def _hlo_regions(compiled):
    """Per-fused-region cost table of one compiled program (or None when
    the backend's HLO text defeats the parser) — attached to the modeled
    step spans and exported as dryrun metrics. The program is already
    compiled; the walk is pure text parsing."""
    try:
        from repro.roofline import region_table
        from repro.roofline.analysis import V5E
        return region_table(compiled.as_text(),
                            peak_flops=V5E.peak_flops, hbm_bw=V5E.hbm_bw)
    except Exception:
        return None


def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool,
                opt_name: str = "local_adaalter", H: int = 4,
                compression: str = "", verbose: bool = True,
                optimized: bool = False, flat: bool = False,
                recorder=None, registry=None) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh); return the roofline record(s).

    ``compression`` selects the sync wire codec. The compiled sync_step then
    contains the codec's encode/decode (its FLOP/memory cost is measured),
    but the in-process simulation all-reduces the *decoded* payload — the
    HLO collective bytes stay at master-dtype size. Each train record
    therefore carries ``modeled_sync_payload_bytes`` (what a codec-aware
    collective would move) next to the measured ``collective_bytes_per_chip``
    so the modeled-vs-measured sync volume can be compared per compiled step
    (ROADMAP item): e.g. biglstm/train_4k sync_step measures ~1.7e10 B/chip
    while int8 models ~1.7e9 — the 10x gap is the future fused
    quantize-into-collective kernel's headroom.
    """
    cfg = get_arch(arch)
    if optimized:
        cfg = dataclasses.replace(cfg, **OPT_FLAGS)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = _mesh_name(mesh)
    n_chips = mesh.size
    t0 = time.time()
    records = []

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(name=opt_name, H=H, compression=compression,
                                  flat=flat)
        plan = resolve_plan(cfg, mesh, optimizer=opt_name)
        # remat="save_tp" was tried and REFUTED on qwen2-7b (§Perf iter 3):
        # -1.0s collective, +6.9s memory. But remat="full" for small
        # memory-bound models (mamba2: stacked f32 residuals x48 layers
        # dominate) trades negligible FLOPs for the stacked saves:
        if optimized and plan.remat == "none":
            plan = dataclasses.replace(plan, remat="full")
        with mesh:
            programs = build_train_programs(cfg, shape, opt_cfg, mesh, plan)
            abstract = jax.eval_shape(programs.init_fn, jax.random.PRNGKey(0))
            params, opt_state = _abstract(abstract[0]), _abstract(abstract[1])
            batch = train_batch_specs(
                cfg, shape, programs.n_workers if programs.is_local else 0)
            from repro.core.sync_engine import make_sync_engine
            from repro.models.counting import count_params
            n_params = count_params(cfg)
            engine = make_sync_engine(
                opt_cfg, is_local=programs.is_local,
                H=programs.H if programs.is_local else 1)
            variants = [("local_step", programs.local_step)]
            if programs.is_local:
                variants.append(("sync_step", programs.sync_step))
            # launch/latency (alpha-beta) model of one sync round issued
            # per-leaf (one small collective per payload leaf) vs as the
            # flat plane's single collective — the dispatch-layer overhead
            # the flat parameter plane removes (core/flatspace.py)
            from repro.core import comm
            n_leaves = programs.n_payload_leaves
            per_leaf_colls = comm.round_collectives(opt_name, n_leaves)
            for vname, fn in variants:
                t_compile0 = recorder.now() if recorder is not None else 0.0
                lowered = fn.lower(params, opt_state, batch)
                compiled = lowered.compile()
                rep = analyze(compiled, arch=arch, shape_name=shape_name,
                              mesh_name=mesh_name, n_chips=n_chips,
                              model_flops_total=model_flops(cfg, shape))
                rec = rep.to_dict()
                # codec-modeled per-worker sync payload for THIS variant, to
                # compare against the measured HLO collective bytes above
                modeled = (engine.round_bytes(n_params)
                           if vname == "sync_step" else 0.0)
                coll_model = None
                if vname == "sync_step":
                    R_ = programs.n_workers
                    coll_model = {
                        "n_payload_leaves": n_leaves,
                        "per_leaf": {
                            "n_collectives": per_leaf_colls,
                            "time_s": comm.collective_time(
                                modeled, per_leaf_colls, R_,
                                cross_pod=multi_pod)},
                        "flat": {
                            "n_collectives": 1,
                            "time_s": comm.collective_time(
                                modeled, 1, R_, cross_pod=multi_pod)},
                    }
                rec.update(variant=vname, plan=dataclasses.asdict(plan),
                           n_workers=programs.n_workers, H=programs.H,
                           optimizer=opt_name,
                           compression=opt_cfg.compression,
                           flat=flat,
                           modeled_sync_payload_bytes=modeled,
                           sync_collective_model=coll_model,
                           memory_analysis=str(compiled.memory_analysis()),
                           compile_s=round(time.time() - t0, 1))
                records.append(rec)
                hlo_tab = (_hlo_regions(compiled)
                           if (recorder is not None or registry) else None)
                if registry:
                    registry.set_many(
                        {"compile_s": rec["compile_s"],
                         "t_compute_s": rec["t_compute_s"],
                         "t_memory_s": rec["t_memory_s"],
                         "t_collective_s": rec["t_collective_s"]},
                        arch=arch, shape=shape_name, mesh=mesh_name,
                        variant=vname)
                if recorder is not None:
                    # one timeline entry per compiled variant: the measured
                    # compile wall, the roofline-modeled step time, and (for
                    # sync_step) the alpha-beta wire model per layout
                    t_now = recorder.now()
                    tag = f"{arch}/{shape_name}/{mesh_name}"
                    recorder.add("eval", step=len(records) - 1,
                                 t0=t_compile0, dur=t_now - t_compile0,
                                 pair=tag, variant=vname, phase="compile")
                    modeled_step = (max(rec["t_compute_s"],
                                        rec["t_memory_s"])
                                    + rec["t_collective_s"])
                    hlo_args = ({"hlo_optimal_s": hlo_tab["optimal_s"],
                                 "hlo_regions": hlo_tab["regions"]}
                                if hlo_tab else {})
                    recorder.add("local_step", step=len(records) - 1,
                                 t0=t_now, dur=modeled_step, modeled=True,
                                 pair=tag, variant=vname,
                                 t_compute_s=rec["t_compute_s"],
                                 t_memory_s=rec["t_memory_s"],
                                 t_collective_s=rec["t_collective_s"],
                                 dominant=rec["dominant"], **hlo_args)
                    if coll_model is not None:
                        layout = "flat" if flat else "per_leaf"
                        m = coll_model[layout]
                        recorder.add("collective", step=len(records) - 1,
                                     t0=t_now + modeled_step,
                                     dur=m["time_s"], modeled=True,
                                     pair=tag, variant=vname, layout=layout,
                                     wire_bytes=modeled,
                                     n_collectives=m["n_collectives"])
                if verbose:
                    print(f"  [{vname}] {rep.summary()}")
                    print(f"  [{vname}] mem: {compiled.memory_analysis()}")
    else:
        plan = serve_plan(cfg, mesh)
        t_compile0 = recorder.now() if recorder is not None else 0.0
        with mesh:
            programs = build_serve_programs(cfg, shape, mesh, plan)
            specs = serve_batch_specs(cfg, shape)
            abstract_params = jax.eval_shape(
                programs.init_fn, jax.random.PRNGKey(0))
            params = _abstract(abstract_params)
            if shape.kind == "prefill":
                lowered = programs.prefill.lower(params, specs["prefill"])
                vname = "prefill"
            else:
                caches = _abstract(decode_cache_specs(cfg, shape))
                lowered = programs.decode_step.lower(
                    params, caches, specs["token"], specs["pos"])
                vname = "decode_step"
            compiled = lowered.compile()
            rep = analyze(compiled, arch=arch, shape_name=shape_name,
                          mesh_name=mesh_name, n_chips=n_chips,
                          model_flops_total=model_flops(cfg, shape))
            rec = rep.to_dict()
            rec.update(variant=vname, plan=dataclasses.asdict(plan),
                       cache_len=programs.cache_len, window=programs.window,
                       memory_analysis=str(compiled.memory_analysis()),
                       compile_s=round(time.time() - t0, 1))
            records.append(rec)
            hlo_tab = (_hlo_regions(compiled)
                       if (recorder is not None or registry) else None)
            if registry:
                registry.set_many(
                    {"compile_s": rec["compile_s"],
                     "t_compute_s": rec["t_compute_s"],
                     "t_memory_s": rec["t_memory_s"],
                     "t_collective_s": rec["t_collective_s"]},
                    arch=arch, shape=shape_name, mesh=mesh_name,
                    variant=vname)
            if recorder is not None:
                t_now = recorder.now()
                tag = f"{arch}/{shape_name}/{mesh_name}"
                recorder.add("eval", step=len(records) - 1, t0=t_compile0,
                             dur=t_now - t_compile0, pair=tag,
                             variant=vname, phase="compile")
                modeled_step = (max(rec["t_compute_s"], rec["t_memory_s"])
                                + rec["t_collective_s"])
                hlo_args = ({"hlo_optimal_s": hlo_tab["optimal_s"],
                             "hlo_regions": hlo_tab["regions"]}
                            if hlo_tab else {})
                recorder.add("local_step", step=len(records) - 1, t0=t_now,
                             dur=modeled_step, modeled=True, pair=tag,
                             variant=vname,
                             t_compute_s=rec["t_compute_s"],
                             t_memory_s=rec["t_memory_s"],
                             t_collective_s=rec["t_collective_s"],
                             dominant=rec["dominant"], **hlo_args)
            if verbose:
                print(f"  [{vname}] {rep.summary()}")
                print(f"  [{vname}] mem: {compiled.memory_analysis()}")

    return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "records": records, "elapsed_s": round(time.time() - t0, 1)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"architecture id, 'all', or 'assigned' ({sorted(ARCHS)})")
    ap.add_argument("--shape", default="all", help=f"one of {sorted(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--optimizer", default="local_adaalter")
    ap.add_argument("--H", type=int, default=4)
    from repro.core.codecs import CODEC_NAMES
    ap.add_argument("--compress", nargs="?", const="int8", default="",
                    choices=["", *CODEC_NAMES], metavar="SCHEME",
                    help="sync wire codec — adds the codec's encode/decode "
                         "to the compiled sync_step and records its "
                         "modeled_sync_payload_bytes next to the measured "
                         "HLO collective bytes")
    ap.add_argument("--out", default="", help="directory for per-pair JSON records")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record compile walls + roofline-modeled step/wire "
                         "spans across all pairs as a repro.trace timeline")
    ap.add_argument("--metrics", default="", metavar="OUT.jsonl",
                    help="export per-pair dryrun metrics (repro.obs): "
                         "compile wall and roofline terms per (arch, shape, "
                         "mesh, variant) as JSONL rows + a Prometheus "
                         "textfile snapshot (OUT.prom)")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the beyond-paper perf flags (§Perf '+opt')")
    ap.add_argument("--flat", action="store_true",
                    help="compile the flat-parameter-plane step builders "
                         "(core/flatspace.py): one update launch + one sync "
                         "collective; records carry the per-leaf vs flat "
                         "alpha-beta collective model either way")
    args = ap.parse_args()

    archs = (ASSIGNED if args.arch == "assigned"
             else sorted(ARCHS) if args.arch == "all" else [args.arch])
    shapes = sorted(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    recorder = None
    if args.trace:
        from repro.trace import TraceRecorder
        recorder = TraceRecorder(meta={
            "kind": "dryrun", "optimizer": args.optimizer, "H": args.H,
            "compression": args.compress, "flat": args.flat,
            "clock": "perf_counter"})
    from repro.obs import NULL_REGISTRY
    registry = NULL_REGISTRY
    if args.metrics:
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry(labels={
            "kind": "dryrun", "optimizer": args.optimizer,
            "codec": args.compress or "fp32"})
        registry.open_jsonl(args.metrics)

    n_ok = n_fail = 0
    n_pair = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
                print(f"== {tag}", flush=True)
                try:
                    result = dryrun_pair(arch, shape_name, multi_pod=multi_pod,
                                         opt_name=args.optimizer, H=args.H,
                                         compression=args.compress,
                                         optimized=args.optimized,
                                         flat=args.flat, recorder=recorder,
                                         registry=registry)
                    n_ok += 1
                    if registry:
                        registry.counter("pairs_ok_total").inc()
                    if args.out:
                        os.makedirs(args.out, exist_ok=True)
                        fn = (f"{arch}_{shape_name}_"
                              f"{'multi' if multi_pod else 'single'}"
                              f"{'_opt' if args.optimized else ''}.json")
                        with open(os.path.join(args.out, fn), "w") as f:
                            json.dump(result, f, indent=1)
                    print(f"   OK in {result['elapsed_s']}s", flush=True)
                except Exception:
                    n_fail += 1
                    if registry:
                        registry.counter("pairs_failed_total").inc()
                    print(f"   FAIL: {tag}\n{traceback.format_exc()}", flush=True)
                if registry:     # one metrics row per attempted pair
                    registry.collect(n_pair)
                n_pair += 1
    if recorder is not None:
        recorder.save(args.trace)
        print(f"wrote trace {args.trace} ({len(recorder.spans)} spans)")
    if registry:
        base = (args.metrics[:-len(".jsonl")]
                if args.metrics.endswith(".jsonl") else args.metrics)
        registry.write_prom(base + ".prom")
        registry.close()
        print(f"wrote metrics {args.metrics} "
              f"(+ Prometheus textfile {base + '.prom'})")
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
