"""Distributed train/serve step builders (pjit + vmap-over-workers).

Training with a *local* optimizer (the paper's Algorithms 2/4):
  * every trainable array and accumulator carries a leading worker axis R,
    physically sharded over ``plan.local_axes`` — per-device memory equals
    plain data parallelism, but replicas may diverge between syncs;
  * ``train_step(..., do_sync=False)`` — H-1 out of H steps — contains NO
    collective over the worker axes (the paper's skipped rounds);
  * ``train_step(..., do_sync=True)`` adds the params+accumulator average
    (Alg. 4 lines 11-12), which GSPMD lowers to the 2·P all-reduce the paper
    charges 2/H per step for.
  The two variants are compiled separately (static ``do_sync``) so the
  dry-run can attribute collective bytes to each and report the amortized
  ``local + sync/H`` volume exactly. *Which* variant runs each step is the
  ``SyncEngine``'s call (``core/sync_engine.py``, host-side): to feed its
  adaptive (CADA-style) policy — and only when it is configured — the local
  train steps additionally emit ``metrics['drift']``, the statistic
  ``SyncConfig.drift_metric`` selects: ``update_norm`` (per-worker parameter
  movement of the step relative to the parameter norm) or ``grad_staleness``
  (CADA-proper ‖g_t − g_last_sync‖² against the ``g_anchor`` state leaf,
  which sync steps re-anchor). Either statistic reduces each worker to a
  scalar *before* the (R,)-sized cross-worker mean, so the skipped rounds
  stay communication-free in any meaningful sense. Under the same opt-in
  pattern, ``OptimizerConfig.obs_metrics`` compiles in
  ``metrics['grad_norm']`` — the per-worker L2 of the raw (pre-clip)
  gradients — for the ``obs`` health probes and trace span args.
  With ``SyncConfig.compression`` set ('int8', 'bf16') the sync payload
  rides the corresponding ``WireCodec`` (``core/codecs.py``; error feedback)
  via the ``compressed_sync`` shim inside ``opt.sync`` — fused into a
  one-HBM-pass Pallas kernel when the codec provides it
  (``kernels/sync_fused.py``) — so only the sync_step changes; local steps
  stay untouched.

Training with a synchronous optimizer (Alg. 1/3, or models too large for
per-worker replicas): classic data-parallel/FSDP — gradients are implicitly
all-reduced every step by GSPMD.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig, ParallelismPlan, ShapeConfig
from repro.core import optimizers as opt_lib
from repro.models import build_model
from repro.sharding.partition import ShardingRules, use_rules
from repro.sharding.specs import param_shardings, opt_state_shardings, shape_safe_spec


def _axes_entry(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def worker_count(plan: ParallelismPlan, mesh) -> int:
    n = 1
    for ax in plan.local_axes:
        n *= mesh.shape[ax]
    return n


def _batch_sharding(rules: ShardingRules, batch_tree, *, workers: bool):
    mesh, plan = rules.mesh, rules.plan
    w = _axes_entry(tuple(plan.local_axes))
    d = _axes_entry(tuple(plan.grad_axes))

    def one(leaf):
        if workers:
            spec = P(w, d, *([None] * (leaf.ndim - 2)))
        else:
            spec = P(d, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, shape_safe_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map(one, batch_tree)


def _mean_over_workers(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape),
        tree)


def _drift_stat(new_params, params):
    """Per-worker parameter drift of one local step, as a single scalar.

    mean over workers of ||x_i' − x_i|| / (||x_i|| + tiny), every leaf
    carrying a leading worker axis. Each worker reduces to a scalar before
    any cross-worker op, so the only collective this adds is over an
    (R,)-sized vector — the adaptive sync policy accumulates it host-side.
    """
    delta = jax.tree_util.tree_map(
        lambda n, p: n.astype(jnp.float32) - p.astype(jnp.float32),
        new_params, params)
    d = opt_lib.global_norm(delta, batch_ndim=1)
    p = opt_lib.global_norm(params, batch_ndim=1)
    return jnp.mean(d / (p + 1e-12))


def _staleness_stat(grads, anchor):
    """CADA-proper gradient staleness, as a single scalar.

    mean over workers of ‖g_i,t − g_i,last_sync‖² / (‖g_i,t‖² + tiny) —
    the squared distance to the gradient each worker saw at its last sync
    round (kept in the ``g_anchor`` state leaf), normalized by the current
    gradient's energy so the threshold is scale-free. Like
    :func:`_drift_stat`, each worker reduces to a scalar before the
    (R,)-sized cross-worker mean, so skipped rounds stay communication-free.
    The anchor starts at zero, so the first window reads a statistic of
    ~1/step — which triggers an early first sync, a conservative start.
    """
    delta = jax.tree_util.tree_map(
        lambda g, a: g.astype(jnp.float32) - a, grads, anchor)
    d2 = jnp.square(opt_lib.global_norm(delta, batch_ndim=1))
    g2 = jnp.square(opt_lib.global_norm(grads, batch_ndim=1))
    return jnp.mean(d2 / (g2 + 1e-12))


@dataclasses.dataclass
class TrainPrograms:
    """Jitted step functions + their input sharding pytrees.

    With ``OptimizerConfig.flat`` the params/opt_state the step functions
    exchange are FlatSpace planes (core/flatspace.py) instead of per-leaf
    pytrees; the adapter fields below let the train loop translate between
    the two layouts (checkpoint restores work across them in both
    directions) — they are populated whenever the run COULD have a flat
    twin (local Local AdaAlter), not only when ``flat`` is on.
    """
    init_fn: Any                 # (rng) -> (params, opt_state)
    local_step: Any              # (params, opt_state, batch) -> (params, opt_state, metrics)
    sync_step: Any               # same signature; includes the H-th-step averaging
    batch_sharding: Any
    param_sharding: Any
    opt_sharding: Any
    n_workers: int
    is_local: bool
    H: int
    n_payload_leaves: int = 0    # param leaves one sync round touches (the
                                 # per-leaf path issues one collective per
                                 # leaf x the algorithm's round multiplier;
                                 # the flat plane issues ONE regardless)
    is_flat: bool = False
    n_shards: int = 1            # FSDP/TP sub-planes per worker (flat runs):
                                 # each device holds plane_size/n_shards
                                 # elements per worker row, and a sync round
                                 # moves per-shard wire bytes, not full-plane
    flatspace: Any = None        # FlatSpace geometry (local_adaalter runs)
    legacy_abstract: Any = None  # (params, opt_state) per-leaf ShapeDtypeStructs
    flat_abstract: Any = None    # (plane, flat_state) ShapeDtypeStructs
    to_flat: Any = None          # per-leaf (params, opt_state) -> planes
    to_legacy: Any = None        # planes -> per-leaf (params, opt_state)


def build_train_programs(cfg: ModelConfig, shape: ShapeConfig,
                         opt_cfg: OptimizerConfig, mesh,
                         plan: ParallelismPlan) -> TrainPrograms:
    model = build_model(cfg)
    opt = opt_lib.make_optimizer(opt_cfg)
    local = opt_lib.is_local(opt) and bool(plan.local_axes)
    overrides = {}
    if getattr(cfg, "seq_parallel", False):
        overrides["seq_sp"] = "model"
    if getattr(cfg, "expert_axes_2d", False):
        overrides["experts"] = ("model", "data")
    rules = ShardingRules(mesh, plan, overrides or None)
    R = worker_count(plan, mesh) if local else 1
    spmd_axes = tuple(plan.local_axes)

    # ---------------- abstract init (for shardings) ---------------------- #
    def _expand(base):
        """base params (no worker axis) -> (params, opt_state), full layout."""
        if local:
            params = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), base)
            state = jax.vmap(opt.init)(params)
        else:
            params, state = base, opt.init(base)
        return params, state

    def raw_init(rng):
        return _expand(model.init(rng))

    with use_rules(rules):
        abstract = jax.eval_shape(raw_init, jax.random.PRNGKey(0))
    p_sh = param_shardings(rules, abstract[0], with_workers=local)
    s_sh = opt_state_shardings(rules, abstract[1], p_sh, with_workers=local)

    # FlatSpace adapters exist for every run that could have a flat twin
    # (so either layout can restore the other's checkpoints); the flat
    # STEP functions are a separate build below.
    flat_ok = local and opt_cfg.name == "local_adaalter"
    if opt_cfg.flat and not flat_ok:
        raise ValueError(
            "OptimizerConfig.flat requires a local Local AdaAlter run "
            f"(got optimizer={opt_cfg.name!r}, local={local})")
    fs = None
    n_shards = 1
    if flat_ok:
        from repro.core import flatspace as fsp
        from repro.sharding.specs import plane_shard_count
        n_shards = plane_shard_count(mesh, plan)
        fs = fsp.FlatSpace.build(abstract[0], batch_ndim=1, shards=n_shards,
                                 eps=opt_cfg.eps if opt_cfg.flat else None)

    # Two-stage init. The RNG draw compiles UNSHARDED: letting GSPMD partition
    # the threefry computation changes the drawn values whenever a
    # non-trailing dim is sharded, so the same seed produced different weights
    # on different meshes (caught by the sharded-equivalence test). Only the
    # draw is RNG-dependent, so the R-way broadcast and accumulator zeros are
    # built under the target shardings — the unsharded spike is P, not ~5·R·P.
    _draw = jax.jit(model.init)
    _place = jax.jit(_expand, out_shardings=(p_sh, s_sh))

    def init_fn(rng):
        return _place(_draw(rng))

    # ---------------- loss/grad ------------------------------------------ #
    def loss_fn(params, batch):
        with use_rules(rules):
            loss, metrics = model.loss_fn(params, batch, remat=plan.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # ---------------- step bodies ---------------------------------------- #
    if local:
        def _worker(params, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        vworker = jax.vmap(_worker, spmd_axis_name=spmd_axes or None)
        vlocal = jax.vmap(opt.local_step)

        def step(params, opt_state, batch, *, do_sync: bool):
            loss, metrics, grads = vworker(params, batch)
            if opt_cfg.use_pallas and opt_cfg.name == "local_adaalter":
                from repro.kernels.ops import tree_fused_update
                # the fused kernel bypasses opt.local_step, so the grad_clip
                # wrapper never sees these grads — clip per worker here.
                # `grads` itself stays RAW: the drift statistics below must
                # see the same values the non-Pallas path's stat sees (there
                # the wrapper clips inside opt.local_step, after the stat's
                # inputs are captured).
                applied = grads
                if opt_cfg.grad_clip > 0:
                    applied, _ = opt_lib.clip_by_global_norm(
                        grads, opt_cfg.grad_clip, batch_ndim=1)
                step_no = opt_state["step"] + 1
                tprime = opt_state["tprime"] + 1
                eta = opt_lib.warmup_lr(opt_cfg.lr, step_no[0], opt_cfg.warmup_steps)
                extra = tprime[0].astype(jnp.float32) * opt_cfg.eps ** 2
                new_params, new_b2 = tree_fused_update(
                    params, applied, opt_state["b2_sync"], opt_state["b2_local"],
                    eta, extra, use_pallas=True)
                # keep extra leaves (e.g. compressed_sync's error-feedback
                # residuals) instead of rebuilding the dict from scratch
                new_state = {**opt_state, "step": step_no, "tprime": tprime,
                             "b2_local": new_b2}
            else:
                new_params, new_state = vlocal(grads, opt_state, params)
            out_metrics = {"loss": jnp.mean(loss),
                           **{k: jnp.mean(v) for k, v in metrics.items()}}
            if opt_cfg.obs_metrics:
                # per-worker L2 of the RAW (pre-clip) gradients, for the
                # obs health probes — same opt-in pattern as drift below:
                # not compiled into an uninstrumented run at all
                out_metrics["grad_norm"] = opt_lib.global_norm(
                    grads, batch_ndim=1)
            # divergence stat for the adaptive sync policy (its only
            # consumer — fixed_h never reads it, so don't make its hot loop
            # pay the extra full-parameter reductions). Which statistic is
            # the SyncConfig's drift_metric: the per-step relative update
            # norm, or the CADA-proper gradient staleness vs the g_anchor
            # state leaf (with_grad_anchor).
            from repro.core.sync_engine import drift_statistic
            stat = drift_statistic(opt_cfg.sync)
            staleness = stat == "grad_staleness"
            if staleness:
                out_metrics["drift"] = _staleness_stat(
                    grads, opt_state["g_anchor"])
            elif stat is not None:
                out_metrics["drift"] = _drift_stat(new_params, params)
            if do_sync:
                new_params, new_state = opt.sync(new_params, new_state,
                                                 _mean_over_workers)
                if staleness:
                    # re-anchor the staleness statistic at THIS round's
                    # per-worker gradients (the one place they're in scope)
                    new_state = {**new_state, "g_anchor": jax.tree_util.tree_map(
                        lambda g: g.astype(jnp.float32), grads)}
            return new_params, new_state, out_metrics
    else:
        def step(params, opt_state, batch, *, do_sync: bool):
            (loss, metrics), grads = grad_fn(params, batch)
            sq = jax.tree_util.tree_map(lambda g: jnp.square(g.astype(jnp.float32)),
                                        grads)
            if isinstance(opt, opt_lib.LocalOptimizer):
                new_params, new_state = opt.local_step(grads, opt_state, params)
                if do_sync:
                    new_params, new_state = opt.sync(new_params, new_state)
            else:
                new_params, new_state = opt.update(grads, sq, opt_state, params)
            out_metrics = {"loss": loss,
                           **{k: jnp.mean(v) for k, v in metrics.items()}}
            if opt_cfg.obs_metrics:
                out_metrics["grad_norm"] = opt_lib.global_norm(
                    grads, batch_ndim=0)
            return new_params, new_state, out_metrics

    # ---------------- batch specs + jit ----------------------------------- #
    example_batch = train_batch_specs(cfg, shape, R if local else 0)
    b_sh = _batch_sharding(rules, example_batch, workers=local)

    common = dict(
        in_shardings=(p_sh, s_sh, b_sh),
        out_shardings=(p_sh, s_sh, None),
        donate_argnums=(0, 1),
    )
    local_step = jax.jit(partial(step, do_sync=False), **common)
    sync_step = jax.jit(partial(step, do_sync=True), **common)

    # ---------------- flat-plane rebuild (OptimizerConfig.flat) ----------- #
    flat_fields = {}
    if fs is not None:
        from repro.core import flatspace as fsp
        flat_fields = dict(
            flatspace=fs, legacy_abstract=abstract,
            flat_abstract=fsp.flat_abstract(fs, abstract[0], abstract[1]),
            to_flat=lambda p_, s_: (fs.pack(p_), fsp.pack_opt_state(fs, s_)),
            to_legacy=lambda pl_, st_: (fs.unpack(pl_),
                                        fsp.unpack_opt_state(fs, st_)))
    if opt_cfg.flat:
        init_fn, local_step, sync_step, p_sh, s_sh = _flat_programs(
            fs, opt_cfg, mesh, plan, R, abstract, _expand, _draw, vworker,
            b_sh, leaf_p_sh=p_sh)

    return TrainPrograms(
        init_fn=init_fn, local_step=local_step, sync_step=sync_step,
        batch_sharding=b_sh, param_sharding=p_sh, opt_sharding=s_sh,
        n_workers=R, is_local=local,
        H=getattr(opt, "H", 1) if opt_lib.is_local(opt) else 1,
        n_payload_leaves=len(jax.tree_util.tree_leaves(abstract[0])),
        is_flat=opt_cfg.flat, n_shards=n_shards, **flat_fields)


# --------------------------------------------------------------------------- #
# flat-plane step builders (OptimizerConfig.flat; core/flatspace.py)
# --------------------------------------------------------------------------- #
def _flat_programs(fs, opt_cfg: OptimizerConfig, mesh, plan, R: int,
                   abstract, _expand, _draw, vworker, b_sh, *, leaf_p_sh):
    """Local AdaAlter over FlatSpace planes: the whole per-step update is
    ONE Pallas launch over the packed plane (vs one per leaf), and the sync
    round is ONE fused EF kernel + ONE all-reduce of a single flat wire
    array (vs 2·L small collectives). Given the same schedule the train
    STATE is bitwise identical to the per-leaf path — both with
    ``use_pallas`` (kernel vs kernel) and without (the jnp fallbacks mirror
    each other's cast orders); pinned by tests/test_flat_step.py. Derived
    scalars (loss, the adaptive drift statistic below — computed over the
    plane rather than leaf-by-leaf) are reduction-order-dependent and may
    differ in ulps between the two compiled programs, so an adaptive
    schedule can diverge at a threshold edge; fixed_h cannot.

    When the plan carries FSDP/TP axes the mesh can use
    (``sharding.partition.plane_shard_axes``), each worker row of every
    plane is additionally split into ``fs.shards`` contiguous tile-aligned
    sub-planes, one per device down the shard axes. The flat kernels then
    run shard-local under ``shard_map`` (pallas_call has no partitioning
    rule) with per-shard sidecar views, the ``[params ‖ B²]`` sync payload
    is concatenated shard-locally (shard boundaries are block boundaries,
    so the blocked quantization partitions the same elements), and the sync
    mean reduces over the WORKER axes only — sharded slots stay partitioned
    through the round. The unpacked per-leaf param views are pinned to the
    per-leaf shardings (``leaf_p_sh``) so the model forward compiles to the
    same sharded program whether the plane is replicated or sharded —
    that, plus the shard-local kernels being elementwise/block-exact, is
    what keeps sharded-flat bitwise equal to replicated-flat (pinned by
    tests/test_flat_sharded.py).

    Returns ``(init_fn, local_step, sync_step, p_sh, s_sh)`` where the
    state layout is (plane, {scalars + per-state planes}).
    """
    import numpy as np

    from repro.core.flatspace import (SCALAR_STATE_KEYS, mean_planes,
                                      pack_opt_state)
    from repro.core.sync_engine import drift_statistic
    from repro.kernels.adaalter_update import LANES as _LANES
    from repro.kernels.ops import on_tpu
    from repro.sharding.specs import plane_shardings

    if opt_cfg.eps <= 0:
        raise ValueError("flat mode requires eps > 0: the zero slot padding "
                         "must stay zero through rsqrt(B² + t'·ε²)")
    sync_cfg = opt_cfg.sync
    psize = fs.plane_size
    lossless = sync_cfg.compression in ("", "fp32")
    block = sync_cfg.block
    if psize % block or fs.align % block:
        raise ValueError(f"sync block {block} must divide the FlatSpace "
                         f"alignment {fs.align}")
    # sidecars, built once: where the plane must round through bf16, and
    # the per-block lower clamp of the [params ‖ B²] sync payload
    elems = fs.round16_elems()                               # (P,) bool
    upd_rnd_rows = np.tile(fs.rows_sidecar(elems, _LANES), (R, 1))
    sync_rnd_elems = np.concatenate([elems, np.zeros(psize, np.bool_)])
    sync_rnd_blocks = fs.rows_sidecar(sync_rnd_elems, block)
    f32min = float(jnp.finfo(jnp.float32).min)
    sync_low_elems = np.concatenate(
        [np.full(psize, f32min, np.float32), np.zeros(psize, np.float32)])
    sync_low_blocks = sync_low_elems.reshape(-1, block)[:, :1]
    stat = drift_statistic(sync_cfg)
    staleness = stat == "grad_staleness"

    w_entry = _axes_entry(tuple(plan.local_axes))
    plane_sh, scalar_sh, shard_axes = plane_shardings(mesh, plan)
    n_shards = 1
    for a in shard_axes:
        n_shards *= mesh.shape[a]
    assert fs.shards == n_shards, (fs.shards, n_shards, shard_axes)
    sharded = n_shards > 1
    p_sh = plane_sh
    s_sh = {k: (scalar_sh if k in SCALAR_STATE_KEYS else plane_sh)
            for k in abstract[1]}

    # ---------------- shard-local kernel wrappers (n_shards > 1) --------- #
    # pallas_call has no GSPMD partitioning rule, so the sharded plane runs
    # the flat kernels shard-local under shard_map: each device sees its
    # (R_local, plane_size/n_shards) sub-planes plus per-shard sidecar
    # VIEWS (the sidecars are shard_map inputs sharded over the shard axes,
    # i.e. slices indexed relative to the shard origin). Everything inside
    # is elementwise or blocked within a shard, and shard boundaries land
    # on tile/block boundaries, so shard-local bits == replicated bits.
    if sharded:
        from jax.experimental.shard_map import shard_map

        s_entry = _axes_entry(shard_axes)
        pspec = P(w_entry, s_entry)
        side_spec = P(s_entry, None)
        upd_rnd_pw = fs.rows_sidecar(elems, _LANES)       # (P//LANES, 1)
        enc_rnd_pw = fs.rows_sidecar(elems, block)        # (P//block, 1)

        def _upd_local(x, g, bs, bl, eta, extra, rnd):
            if opt_cfg.use_pallas:
                from repro.kernels.adaalter_update import flat_fused_update
                return flat_fused_update(x, g, bs, bl, eta, extra, rnd,
                                         interpret=not on_tpu())
            from repro.kernels.ref import flat_fused_update_ref
            e16 = jnp.broadcast_to(rnd > 0,
                                   (rnd.shape[0], _LANES)).reshape(-1)
            return flat_fused_update_ref(x, g, bs, bl, eta, extra, e16)

        _upd_sharded = shard_map(
            _upd_local, mesh=mesh,
            in_specs=(pspec, pspec, pspec, pspec, P(), P(), side_spec),
            out_specs=(pspec, pspec), check_rep=False)

        def _enc_local(pp, bb, rp, rb, rndp):
            # shard-local [params ‖ B²] concat: the boundary sits at a
            # multiple of align (hence block), so every quantization block
            # holds exactly the elements the replicated concat's would
            nb = rndp.shape[0]
            rnd = jnp.concatenate([rndp, jnp.zeros_like(rndp)], 0)
            low = jnp.concatenate(
                [jnp.full((nb, 1), f32min, jnp.float32),
                 jnp.zeros((nb, 1), jnp.float32)], 0)
            payload = jnp.concatenate([pp, bb], -1)
            res = jnp.concatenate([rp, rb], -1)
            half = pp.shape[-1]
            if sync_cfg.compression == "int8":
                from repro.kernels.sync_fused import flat_ef_plane
                wire, nres = flat_ef_plane(
                    payload, res, rnd, low, block=block,
                    use_pallas=opt_cfg.use_pallas, fused=sync_cfg.fused)
            else:       # bf16 wire: elementwise EF roundtrip
                from repro.kernels.tiling import round_through_bf16
                low_e = jnp.broadcast_to(low, (2 * nb, block)).reshape(-1)
                rnd_e = jnp.broadcast_to(rnd > 0,
                                         (2 * nb, block)).reshape(-1)
                v = payload + res
                vq = jnp.maximum(round_through_bf16(v), low_e)
                wire = jnp.where(rnd_e, round_through_bf16(vq), vq)
                nres = v - wire
            return (wire[..., :half], wire[..., half:],
                    nres[..., :half], nres[..., half:])

        _enc_sharded = shard_map(
            _enc_local, mesh=mesh,
            in_specs=(pspec, pspec, pspec, pspec, side_spec),
            out_specs=(pspec, pspec, pspec, pspec), check_rep=False)

    def _expand_flat(base):
        params, state = _expand(base)
        return fs.pack(params), pack_opt_state(fs, state)

    _place = jax.jit(_expand_flat, out_shardings=(p_sh, s_sh))

    def init_fn(rng):
        return _place(_draw(rng))

    def flat_sync_sharded(new_plane, new_state):
        """Alg. 4 lines 11-12 with a sharded plane: the EF encode runs
        shard-local, and the wire mean reduces over the WORKER axes only —
        GSPMD all-reduces each device's sub-plane across its worker
        replicas while the shard (FSDP/TP) slots stay partitioned, so the
        round moves per-shard wire bytes per device, not full-plane."""
        b2 = new_state["b2_local"]
        if lossless:
            wire_p, wire_b = new_plane, b2
            nres_p = nres_b = None
        else:
            wire_p, wire_b, nres_p, nres_b = _enc_sharded(
                new_plane, b2, new_state["res_params"],
                new_state["res_b2"], jnp.asarray(enc_rnd_pw))
        mean_p = mean_planes(wire_p, elems)        # worker-axes collective
        mean_b = mean_planes(wire_b, None)
        out_state = {**new_state,
                     "tprime": jnp.zeros_like(new_state["tprime"]),
                     "b2_sync": mean_b, "b2_local": mean_b}
        if nres_p is not None:
            out_state["res_params"] = nres_p
            out_state["res_b2"] = nres_b
        return mean_p, out_state

    def flat_sync(new_plane, new_state):
        """Alg. 4 lines 11-12 over the packed payload — one wire array."""
        if sharded:
            return flat_sync_sharded(new_plane, new_state)
        payload = jnp.concatenate([new_plane, new_state["b2_local"]], -1)
        new_res = None
        if lossless:
            wire = payload
        elif sync_cfg.compression == "int8":
            from repro.kernels.sync_fused import flat_ef_plane
            res = jnp.concatenate([new_state["res_params"],
                                   new_state["res_b2"]], -1)
            wire, new_res = flat_ef_plane(
                payload, res, sync_rnd_blocks, sync_low_blocks, block=block,
                use_pallas=opt_cfg.use_pallas, fused=sync_cfg.fused)
        else:   # bf16 wire: elementwise EF roundtrip, same bits per leaf
            from repro.kernels.tiling import round_through_bf16
            res = jnp.concatenate([new_state["res_params"],
                                   new_state["res_b2"]], -1)
            v = payload + res
            # the codec truncates EVERY payload (B² included); the wire
            # cast then re-rounds only the bf16 param slots (a no-op)
            vq = jnp.maximum(round_through_bf16(v),
                             jnp.asarray(sync_low_elems))
            wire = jnp.where(jnp.asarray(sync_rnd_elems),
                             round_through_bf16(vq), vq)
            new_res = v - wire
        mean = mean_planes(wire, sync_rnd_elems)       # the ONE collective
        b2m = mean[..., psize:]
        out_state = {**new_state,
                     "tprime": jnp.zeros_like(new_state["tprime"]),
                     "b2_sync": b2m, "b2_local": b2m}
        if new_res is not None:
            out_state["res_params"] = new_res[..., :psize]
            out_state["res_b2"] = new_res[..., psize:]
        return mean[..., :psize], out_state

    def step(plane, fstate, batch, *, do_sync: bool):
        # pin the unpacked per-leaf views to the SAME per-leaf shardings
        # the non-flat path trains under: the forward then compiles to one
        # sharded program regardless of how the plane itself is laid out
        # (replicated vs sharded plane → identical grads, bit for bit)
        p_tree = jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            fs.unpack(plane), leaf_p_sh)
        loss, metrics, grads = vworker(p_tree, batch)
        applied = grads
        if opt_cfg.grad_clip > 0:
            applied, _ = opt_lib.clip_by_global_norm(
                grads, opt_cfg.grad_clip, batch_ndim=1)
        a_plane = jax.lax.with_sharding_constraint(fs.pack(applied),
                                                   plane_sh)
        # the drift statistics must see RAW gradients (same contract as the
        # per-leaf fused path); with clipping off the packed plane is both
        g_plane = (a_plane if (not staleness or opt_cfg.grad_clip <= 0)
                   else jax.lax.with_sharding_constraint(fs.pack(grads),
                                                         plane_sh))
        step_no = fstate["step"] + 1
        tprime = fstate["tprime"] + 1
        eta = opt_lib.warmup_lr(opt_cfg.lr, step_no[0], opt_cfg.warmup_steps)
        extra = tprime[0].astype(jnp.float32) * opt_cfg.eps ** 2
        if sharded:
            new_plane, new_b2 = _upd_sharded(
                plane, a_plane, fstate["b2_sync"], fstate["b2_local"],
                eta, extra, jnp.asarray(upd_rnd_pw))
        elif opt_cfg.use_pallas:
            from repro.kernels.adaalter_update import flat_fused_update
            new_plane, new_b2 = flat_fused_update(
                plane, a_plane, fstate["b2_sync"], fstate["b2_local"],
                eta, extra, jnp.asarray(upd_rnd_rows),
                interpret=not on_tpu())
        else:
            from repro.kernels.ref import flat_fused_update_ref
            new_plane, new_b2 = flat_fused_update_ref(
                plane, a_plane, fstate["b2_sync"], fstate["b2_local"],
                eta, extra, jnp.asarray(elems))
        new_state = {**fstate, "step": step_no, "tprime": tprime,
                     "b2_local": new_b2}
        out_metrics = {"loss": jnp.mean(loss),
                       **{k: jnp.mean(v) for k, v in metrics.items()}}
        if opt_cfg.obs_metrics:
            out_metrics["grad_norm"] = opt_lib.global_norm(
                grads, batch_ndim=1)
        if staleness:
            delta = g_plane - fstate["g_anchor"]
            d2 = jnp.sum(jnp.square(delta), axis=-1)
            g2 = jnp.sum(jnp.square(g_plane), axis=-1)
            out_metrics["drift"] = jnp.mean(d2 / (g2 + 1e-12))
        elif stat is not None:
            d = jnp.sqrt(jnp.sum(jnp.square(new_plane - plane), -1))
            pn = jnp.sqrt(jnp.sum(jnp.square(plane), -1))
            out_metrics["drift"] = jnp.mean(d / (pn + 1e-12))
        if do_sync:
            new_plane, new_state = flat_sync(new_plane, new_state)
            if staleness:
                new_state = {**new_state, "g_anchor": g_plane}
        return new_plane, new_state, out_metrics

    common = dict(in_shardings=(p_sh, s_sh, b_sh),
                  out_shardings=(p_sh, s_sh, None),
                  donate_argnums=(0, 1))
    return (init_fn, jax.jit(partial(step, do_sync=False), **common),
            jax.jit(partial(step, do_sync=True), **common), p_sh, s_sh)


# --------------------------------------------------------------------------- #
# abstract input specs (ShapeDtypeStructs — never allocated)
# --------------------------------------------------------------------------- #
def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, n_workers: int = 0):
    """n_workers > 0 -> leading worker axis with per-worker batch slices."""
    S = shape.seq_len
    if n_workers:
        assert shape.global_batch % n_workers == 0, (shape, n_workers)
        lead = (n_workers, shape.global_batch // n_workers)
    else:
        lead = (shape.global_batch,)
    toks = jax.ShapeDtypeStruct(lead + (S,), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["audio_frames"] = jax.ShapeDtypeStruct(
            lead + (S, cfg.d_model), jnp.bfloat16)
    return batch
