"""Distributed train/serve step builders (pjit + vmap-over-workers).

Training with a *local* optimizer (the paper's Algorithms 2/4):
  * every trainable array and accumulator carries a leading worker axis R,
    physically sharded over ``plan.local_axes`` — per-device memory equals
    plain data parallelism, but replicas may diverge between syncs;
  * ``train_step(..., do_sync=False)`` — H-1 out of H steps — contains NO
    collective over the worker axes (the paper's skipped rounds);
  * ``train_step(..., do_sync=True)`` adds the params+accumulator average
    (Alg. 4 lines 11-12), which GSPMD lowers to the 2·P all-reduce the paper
    charges 2/H per step for.
  The two variants are compiled separately (static ``do_sync``) so the
  dry-run can attribute collective bytes to each and report the amortized
  ``local + sync/H`` volume exactly. *Which* variant runs each step is the
  ``SyncEngine``'s call (``core/sync_engine.py``, host-side): to feed its
  adaptive (CADA-style) policy — and only when it is configured — the local
  train steps additionally emit ``metrics['drift']``, the statistic
  ``SyncConfig.drift_metric`` selects: ``update_norm`` (per-worker parameter
  movement of the step relative to the parameter norm) or ``grad_staleness``
  (CADA-proper ‖g_t − g_last_sync‖² against the ``g_anchor`` state leaf,
  which sync steps re-anchor). Either statistic reduces each worker to a
  scalar *before* the (R,)-sized cross-worker mean, so the skipped rounds
  stay communication-free in any meaningful sense.
  With ``SyncConfig.compression`` set ('int8', 'bf16') the sync payload
  rides the corresponding ``WireCodec`` (``core/codecs.py``; error feedback)
  via the ``compressed_sync`` shim inside ``opt.sync`` — fused into a
  one-HBM-pass Pallas kernel when the codec provides it
  (``kernels/sync_fused.py``) — so only the sync_step changes; local steps
  stay untouched.

Training with a synchronous optimizer (Alg. 1/3, or models too large for
per-worker replicas): classic data-parallel/FSDP — gradients are implicitly
all-reduced every step by GSPMD.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig, ParallelismPlan, ShapeConfig
from repro.core import optimizers as opt_lib
from repro.models import build_model
from repro.sharding.partition import ShardingRules, use_rules
from repro.sharding.specs import param_shardings, opt_state_shardings, shape_safe_spec


def _axes_entry(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def worker_count(plan: ParallelismPlan, mesh) -> int:
    n = 1
    for ax in plan.local_axes:
        n *= mesh.shape[ax]
    return n


def _batch_sharding(rules: ShardingRules, batch_tree, *, workers: bool):
    mesh, plan = rules.mesh, rules.plan
    w = _axes_entry(tuple(plan.local_axes))
    d = _axes_entry(tuple(plan.grad_axes))

    def one(leaf):
        if workers:
            spec = P(w, d, *([None] * (leaf.ndim - 2)))
        else:
            spec = P(d, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, shape_safe_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map(one, batch_tree)


def _mean_over_workers(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape),
        tree)


def _drift_stat(new_params, params):
    """Per-worker parameter drift of one local step, as a single scalar.

    mean over workers of ||x_i' − x_i|| / (||x_i|| + tiny), every leaf
    carrying a leading worker axis. Each worker reduces to a scalar before
    any cross-worker op, so the only collective this adds is over an
    (R,)-sized vector — the adaptive sync policy accumulates it host-side.
    """
    delta = jax.tree_util.tree_map(
        lambda n, p: n.astype(jnp.float32) - p.astype(jnp.float32),
        new_params, params)
    d = opt_lib.global_norm(delta, batch_ndim=1)
    p = opt_lib.global_norm(params, batch_ndim=1)
    return jnp.mean(d / (p + 1e-12))


def _staleness_stat(grads, anchor):
    """CADA-proper gradient staleness, as a single scalar.

    mean over workers of ‖g_i,t − g_i,last_sync‖² / (‖g_i,t‖² + tiny) —
    the squared distance to the gradient each worker saw at its last sync
    round (kept in the ``g_anchor`` state leaf), normalized by the current
    gradient's energy so the threshold is scale-free. Like
    :func:`_drift_stat`, each worker reduces to a scalar before the
    (R,)-sized cross-worker mean, so skipped rounds stay communication-free.
    The anchor starts at zero, so the first window reads a statistic of
    ~1/step — which triggers an early first sync, a conservative start.
    """
    delta = jax.tree_util.tree_map(
        lambda g, a: g.astype(jnp.float32) - a, grads, anchor)
    d2 = jnp.square(opt_lib.global_norm(delta, batch_ndim=1))
    g2 = jnp.square(opt_lib.global_norm(grads, batch_ndim=1))
    return jnp.mean(d2 / (g2 + 1e-12))


@dataclasses.dataclass
class TrainPrograms:
    """Jitted step functions + their input sharding pytrees."""
    init_fn: Any                 # (rng) -> (params, opt_state)
    local_step: Any              # (params, opt_state, batch) -> (params, opt_state, metrics)
    sync_step: Any               # same signature; includes the H-th-step averaging
    batch_sharding: Any
    param_sharding: Any
    opt_sharding: Any
    n_workers: int
    is_local: bool
    H: int


def build_train_programs(cfg: ModelConfig, shape: ShapeConfig,
                         opt_cfg: OptimizerConfig, mesh,
                         plan: ParallelismPlan) -> TrainPrograms:
    model = build_model(cfg)
    opt = opt_lib.make_optimizer(opt_cfg)
    local = opt_lib.is_local(opt) and bool(plan.local_axes)
    overrides = {}
    if getattr(cfg, "seq_parallel", False):
        overrides["seq_sp"] = "model"
    if getattr(cfg, "expert_axes_2d", False):
        overrides["experts"] = ("model", "data")
    rules = ShardingRules(mesh, plan, overrides or None)
    R = worker_count(plan, mesh) if local else 1
    spmd_axes = tuple(plan.local_axes)

    # ---------------- abstract init (for shardings) ---------------------- #
    def _expand(base):
        """base params (no worker axis) -> (params, opt_state), full layout."""
        if local:
            params = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), base)
            state = jax.vmap(opt.init)(params)
        else:
            params, state = base, opt.init(base)
        return params, state

    def raw_init(rng):
        return _expand(model.init(rng))

    with use_rules(rules):
        abstract = jax.eval_shape(raw_init, jax.random.PRNGKey(0))
    p_sh = param_shardings(rules, abstract[0], with_workers=local)
    s_sh = opt_state_shardings(rules, abstract[1], p_sh, with_workers=local)

    # Two-stage init. The RNG draw compiles UNSHARDED: letting GSPMD partition
    # the threefry computation changes the drawn values whenever a
    # non-trailing dim is sharded, so the same seed produced different weights
    # on different meshes (caught by the sharded-equivalence test). Only the
    # draw is RNG-dependent, so the R-way broadcast and accumulator zeros are
    # built under the target shardings — the unsharded spike is P, not ~5·R·P.
    _draw = jax.jit(model.init)
    _place = jax.jit(_expand, out_shardings=(p_sh, s_sh))

    def init_fn(rng):
        return _place(_draw(rng))

    # ---------------- loss/grad ------------------------------------------ #
    def loss_fn(params, batch):
        with use_rules(rules):
            loss, metrics = model.loss_fn(params, batch, remat=plan.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # ---------------- step bodies ---------------------------------------- #
    if local:
        def _worker(params, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        vworker = jax.vmap(_worker, spmd_axis_name=spmd_axes or None)
        vlocal = jax.vmap(opt.local_step)

        def step(params, opt_state, batch, *, do_sync: bool):
            loss, metrics, grads = vworker(params, batch)
            if opt_cfg.use_pallas and opt_cfg.name == "local_adaalter":
                from repro.kernels.ops import tree_fused_update
                # the fused kernel bypasses opt.local_step, so the grad_clip
                # wrapper never sees these grads — clip per worker here.
                # `grads` itself stays RAW: the drift statistics below must
                # see the same values the non-Pallas path's stat sees (there
                # the wrapper clips inside opt.local_step, after the stat's
                # inputs are captured).
                applied = grads
                if opt_cfg.grad_clip > 0:
                    applied, _ = opt_lib.clip_by_global_norm(
                        grads, opt_cfg.grad_clip, batch_ndim=1)
                step_no = opt_state["step"] + 1
                tprime = opt_state["tprime"] + 1
                eta = opt_lib.warmup_lr(opt_cfg.lr, step_no[0], opt_cfg.warmup_steps)
                extra = tprime[0].astype(jnp.float32) * opt_cfg.eps ** 2
                new_params, new_b2 = tree_fused_update(
                    params, applied, opt_state["b2_sync"], opt_state["b2_local"],
                    eta, extra, use_pallas=True)
                # keep extra leaves (e.g. compressed_sync's error-feedback
                # residuals) instead of rebuilding the dict from scratch
                new_state = {**opt_state, "step": step_no, "tprime": tprime,
                             "b2_local": new_b2}
            else:
                new_params, new_state = vlocal(grads, opt_state, params)
            out_metrics = {"loss": jnp.mean(loss),
                           **{k: jnp.mean(v) for k, v in metrics.items()}}
            # divergence stat for the adaptive sync policy (its only
            # consumer — fixed_h never reads it, so don't make its hot loop
            # pay the extra full-parameter reductions). Which statistic is
            # the SyncConfig's drift_metric: the per-step relative update
            # norm, or the CADA-proper gradient staleness vs the g_anchor
            # state leaf (with_grad_anchor).
            from repro.core.sync_engine import drift_statistic
            stat = drift_statistic(opt_cfg.sync)
            staleness = stat == "grad_staleness"
            if staleness:
                out_metrics["drift"] = _staleness_stat(
                    grads, opt_state["g_anchor"])
            elif stat is not None:
                out_metrics["drift"] = _drift_stat(new_params, params)
            if do_sync:
                new_params, new_state = opt.sync(new_params, new_state,
                                                 _mean_over_workers)
                if staleness:
                    # re-anchor the staleness statistic at THIS round's
                    # per-worker gradients (the one place they're in scope)
                    new_state = {**new_state, "g_anchor": jax.tree_util.tree_map(
                        lambda g: g.astype(jnp.float32), grads)}
            return new_params, new_state, out_metrics
    else:
        def step(params, opt_state, batch, *, do_sync: bool):
            (loss, metrics), grads = grad_fn(params, batch)
            sq = jax.tree_util.tree_map(lambda g: jnp.square(g.astype(jnp.float32)),
                                        grads)
            if isinstance(opt, opt_lib.LocalOptimizer):
                new_params, new_state = opt.local_step(grads, opt_state, params)
                if do_sync:
                    new_params, new_state = opt.sync(new_params, new_state)
            else:
                new_params, new_state = opt.update(grads, sq, opt_state, params)
            out_metrics = {"loss": loss,
                           **{k: jnp.mean(v) for k, v in metrics.items()}}
            return new_params, new_state, out_metrics

    # ---------------- batch specs + jit ----------------------------------- #
    example_batch = train_batch_specs(cfg, shape, R if local else 0)
    b_sh = _batch_sharding(rules, example_batch, workers=local)

    common = dict(
        in_shardings=(p_sh, s_sh, b_sh),
        out_shardings=(p_sh, s_sh, None),
        donate_argnums=(0, 1),
    )
    local_step = jax.jit(partial(step, do_sync=False), **common)
    sync_step = jax.jit(partial(step, do_sync=True), **common)

    return TrainPrograms(
        init_fn=init_fn, local_step=local_step, sync_step=sync_step,
        batch_sharding=b_sh, param_sharding=p_sh, opt_sharding=s_sh,
        n_workers=R, is_local=local,
        H=getattr(opt, "H", 1) if opt_lib.is_local(opt) else 1)


# --------------------------------------------------------------------------- #
# abstract input specs (ShapeDtypeStructs — never allocated)
# --------------------------------------------------------------------------- #
def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, n_workers: int = 0):
    """n_workers > 0 -> leading worker axis with per-worker batch slices."""
    S = shape.seq_len
    if n_workers:
        assert shape.global_batch % n_workers == 0, (shape, n_workers)
        lead = (n_workers, shape.global_batch // n_workers)
    else:
        lead = (shape.global_batch,)
    toks = jax.ShapeDtypeStruct(lead + (S,), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["audio_frames"] = jax.ShapeDtypeStruct(
            lead + (S, cfg.d_model), jnp.bfloat16)
    return batch
