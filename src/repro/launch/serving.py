"""Serving programs: batched prefill + single-token decode with a KV cache.

Shape semantics (assignment):
  * ``prefill_32k``  lowers ``prefill``  — full forward over S tokens,
    returning last-position logits + primed caches.
  * ``decode_32k`` / ``long_500k`` lower ``decode_step`` — ONE new token
    against a pre-allocated cache of ``cache_len`` entries.

Distribution: no local-SGD worker axis in serving. The request batch is
sharded over every non-model mesh axis; tensor parallelism over ``model``.
The KV cache shards its *sequence* dimension over ``model`` — with GQA
(kv_heads=8 < 16-way TP) the head dimension cannot absorb the model axis, and
a 32k×128-batch bf16 cache replicated per TP group would not fit v5e HBM.
Sequence-sharding the cache is the TPU-idiomatic choice: the one-hot ring
write is elementwise in the sharded dim, and GSPMD turns the softmax
normalization into a cheap per-step all-reduce over ``model``.

``long_500k`` requires sub-quadratic state: SSM/hybrid archs decode from O(1)
recurrent state natively; dense/MoE/VLM/audio archs use the sliding-window
cache variant (``cfg.long_context_mode == 'sliding_window'``, ring buffer of
``cfg.sliding_window`` slots) — an explicit, honest substitution recorded in
DESIGN.md and EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelismPlan, ShapeConfig
from repro.models import build_model
from repro.sharding.partition import ShardingRules, use_rules
from repro.sharding.specs import param_shardings, shape_safe_spec

DEFAULT_LONG_WINDOW = 8192


def serve_plan(cfg: ModelConfig, mesh) -> ParallelismPlan:
    """Serving parallelism: batch over all non-model axes, FSDP big weights."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    big = cfg.param_count() > 20e9
    return ParallelismPlan(
        local_axes=(), grad_axes=dp, fsdp_axes=dp if big else (),
        weight_gather_serving=big, remat="none")


def cache_geometry(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[int, int, int]:
    """-> (cache_len, window, cross_len) for a decode shape."""
    window = 0
    cache_len = shape.seq_len
    if shape.seq_len > 65536:
        # long-context decode: bounded state required (assignment). SSM archs
        # are O(1) natively; others fall back to their sliding-window variant.
        if cfg.family not in ("ssm",):
            window = cfg.sliding_window or DEFAULT_LONG_WINDOW
            cache_len = window
    elif cfg.sliding_window and cfg.long_context_mode != "sliding_window":
        # architectural SWA (e.g. hymba): windowed at every context length
        window = cfg.sliding_window
        cache_len = min(cache_len, window)
    if cfg.family == "ssm":
        cache_len = 0                     # no attention cache at all
    cross_len = 0
    if cfg.cross_attn_every:
        cross_len = cfg.n_image_tokens
    if cfg.is_encdec:
        cross_len = min(shape.seq_len, 32768)   # encoder output length
    return cache_len, window, cross_len


# --------------------------------------------------------------------------- #
# cache shardings
# --------------------------------------------------------------------------- #
def cache_shardings(rules: ShardingRules, cache_abstract, family: str):
    mesh = rules.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    b_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    if family == "lstm":
        def one(leaf):                                   # (B, H)
            spec = P(b_entry, *([None] * (leaf.ndim - 1)))
            return NamedSharding(mesh, shape_safe_spec(leaf.shape, spec, mesh))
        return jax.tree_util.tree_map(one, cache_abstract)

    def one(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        name = next((n for n in reversed(names) if n), "")
        nd = leaf.ndim
        if name in ("kv", "xkv") and nd == 5:            # (g,B,L,kv,hd)
            spec = P(None, b_entry, "model", None, None)
        elif name == "ssm" and nd == 5:                  # (g,B,nh,N,hd)
            spec = P(None, b_entry, "model", None, None)
        elif name == "ssm" and nd == 4:                  # conv tail (g,B,W-1,C)
            spec = P(None, b_entry, None, "model")
        else:
            spec = P(*([None] * nd)) if nd < 2 else P(None, b_entry,
                                                      *([None] * (nd - 2)))
        return NamedSharding(mesh, shape_safe_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class ServePrograms:
    init_fn: Any                  # (rng) -> params
    prefill: Any                  # (params, batch) -> (logits, caches)
    decode_step: Any              # (params, caches, token, pos) -> (logits, caches)
    param_sharding: Any
    cache_sharding: Any
    cache_len: int
    window: int
    cross_len: int


def build_serve_programs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                         plan: ParallelismPlan = None) -> ServePrograms:
    model = build_model(cfg)
    plan = plan or serve_plan(cfg, mesh)
    rules = ShardingRules(mesh, plan)
    cache_len, window, cross_len = cache_geometry(cfg, shape)
    B = shape.global_batch

    abstract_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = param_shardings(rules, abstract_params, with_workers=False)
    init_fn = jax.jit(model.init, out_shardings=p_sh)

    cache_abstract = jax.eval_shape(
        lambda: model.init_cache(B, max(cache_len, 1), windowed=bool(window),
                                 cross_len=cross_len))
    c_sh = cache_shardings(rules, cache_abstract, cfg.family)

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    b_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def _b_shard(shape_tuple):
        return NamedSharding(mesh, shape_safe_spec(
            shape_tuple, P(b_entry, *([None] * (len(shape_tuple) - 1))), mesh))

    def prefill_fn(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch, window=window)

    def decode_fn(params, caches, token, pos):
        with use_rules(rules):
            return model.decode_step(params, caches, token, pos, window=window)

    batch_spec = serve_batch_specs(cfg, shape)
    prefill_b_sh = jax.tree_util.tree_map(lambda l: _b_shard(l.shape),
                                          batch_spec["prefill"])
    prefill_jit = jax.jit(prefill_fn,
                          in_shardings=(p_sh, prefill_b_sh),
                          out_shardings=(None, c_sh))
    decode_jit = jax.jit(decode_fn,
                         in_shardings=(p_sh, c_sh,
                                       _b_shard((B, 1)), _b_shard((B,))),
                         out_shardings=(None, c_sh),
                         donate_argnums=(1,))
    return ServePrograms(init_fn=init_fn, prefill=prefill_jit,
                         decode_step=decode_jit, param_sharding=p_sh,
                         cache_sharding=c_sh, cache_len=cache_len,
                         window=window, cross_len=cross_len)


# --------------------------------------------------------------------------- #
# abstract input specs (dry-run: never allocated)
# --------------------------------------------------------------------------- #
def serve_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for prefill batch and decode-step inputs."""
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.param_dtype)
    prefill_batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.cross_attn_every:
        prefill_batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), dtype)
    if cfg.is_encdec:
        prefill_batch["audio_frames"] = jax.ShapeDtypeStruct(
            (B, min(S, 32768), cfg.d_model), dtype)
    return {
        "prefill": prefill_batch,
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract cache pytree for the decode dry-run (no allocation)."""
    model = build_model(cfg)
    cache_len, window, cross_len = cache_geometry(cfg, shape)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, max(cache_len, 1),
                                 windowed=bool(window), cross_len=cross_len))
