"""Sync-health probes: the convergence-relevant state the papers gate on.

CADA (2012.15469) triggers communication on gradient staleness, Stich's
Local SGD analysis (1805.09767) bounds divergence by the inter-sync drift,
and this paper's error-feedback codec is sound only while the EF residual
stays bounded. These probes derive exactly those quantities host-side from
state the train step already materializes — nothing is added to the
compiled programs except the (gated) ``grad_norm`` metric emission in
``launch.steps``:

  grad_norm          per-worker L2 of the raw gradients (pre-clip), read
                     from the step metrics (emitted when
                     ``OptimizerConfig.obs_metrics`` is on);
  drift              the adaptive policy's accumulated-divergence input;
  ef_residual_norm   per dtype bucket, L2 of the error-feedback residual
                     after the last sync round — growth here means the
                     codec is dropping signal faster than EF recycles it;
  quant_mse          mean squared wire error of the last round. The
                     residual IS the round's quantization error
                     (``res = v − wire`` by construction), so this costs
                     one reduction, no re-encode;
  b2 quantiles       p50/p90/p99/max of the B² (AdaGrad second-moment)
                     accumulator per bucket — the paper's Figure-4
                     "B² keeps growing" story, watchable per step;
  wire_compression_ratio   static: codec round bytes / fp32 round bytes.

Buckets are the FlatSpace dtype buckets (``bucket_ranges``) on flat runs
and the parameter-dtype leaf groups on per-leaf runs, so both layouts
report the same bucket names. One probe serves both the metrics registry
and the trace recorder (``events.health_span_args``), which is what keeps
the two reporting the same numbers.

The device-side reductions are jitted once and only run when a consumer
(registry or trace) is active; residual/MSE summaries additionally only
run on sync rounds (the residual is constant in between).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SyncHealthProbe"]

#: B² quantiles exported per bucket.
B2_QS = (0.5, 0.9, 0.99)


def _np(x) -> np.ndarray:
    return np.asarray(x)


class SyncHealthProbe:
    """Host-side per-step health summary of one training run.

    Build with :meth:`build`; call :meth:`step_summary` once per executed
    step. Returns a JSON-safe nested dict (see module docstring for the
    keys); entries whose inputs don't exist for this run (no lossy codec →
    no residual, SGD → no B²) are simply absent.
    """

    def __init__(self, *, is_flat: bool, flatspace: Any,
                 params_abstract: Any, engine: Any, n_params: int) -> None:
        self.is_flat = bool(is_flat)
        self.fs = flatspace
        self.engine = engine
        self.n_params = int(n_params)
        self._leaf_dtypes: List[str] = []
        if not self.is_flat and params_abstract is not None:
            import jax
            self._leaf_dtypes = [
                np.dtype(l.dtype).name
                for l in jax.tree_util.tree_leaves(params_abstract)]
        self._fn_b2 = None
        self._fn_res = None

    # ------------------------------------------------------------------ #
    @staticmethod
    def build(engine, programs, n_params: int) -> "SyncHealthProbe":
        params_abs = None
        if programs.legacy_abstract is not None:
            params_abs = programs.legacy_abstract[0]
        return SyncHealthProbe(
            is_flat=programs.is_flat, flatspace=programs.flatspace,
            params_abstract=params_abs, engine=engine, n_params=n_params)

    # ------------------------------------------------------------------ #
    def static_summary(self) -> Dict[str, float]:
        """Run-constant facts: wire bytes and compression ratio of one
        sync round under the engine's codec."""
        n = self.n_params
        round_b = float(self.engine.round_bytes(n))
        from repro.core import comm
        fp32_b = float(comm.sync_payload_bytes(self.engine.algorithm, n))
        return {
            "round_wire_bytes": round_b,
            "wire_compression_ratio": fp32_b / round_b if round_b else 1.0,
        }

    # ------------------------------------------------------------------ #
    def _buckets(self, entry) -> List[Tuple[str, Any]]:
        """``(bucket_name, flattened fp32 array)`` views of one opt-state
        entry (a plane on flat runs, a params-shaped pytree otherwise)."""
        import jax
        import jax.numpy as jnp
        if self.is_flat:
            out = {}
            for name, start, stop in self.fs.bucket_ranges():
                piece = entry[..., start:stop].reshape(-1)
                out[name] = (jnp.concatenate([out[name], piece])
                             if name in out else piece)
            return sorted(out.items())
        leaves = jax.tree_util.tree_leaves(entry)
        dtypes = self._leaf_dtypes or ["float32"] * len(leaves)
        out = {}
        for dt, leaf in zip(dtypes, leaves):
            piece = leaf.astype(jnp.float32).reshape(-1)
            out[dt] = (jnp.concatenate([out[dt], piece])
                       if dt in out else piece)
        return sorted(out.items())

    def _build_b2(self, opt_state):
        import jax
        import jax.numpy as jnp

        def fn(state):
            out = {}
            for name, flat in self._buckets(state["b2_local"]):
                qs = jnp.quantile(flat, jnp.asarray(B2_QS))
                out[name] = {**{f"p{int(q * 100)}": qs[i]
                                for i, q in enumerate(B2_QS)},
                             "max": jnp.max(flat)}
            return out

        return jax.jit(fn)

    def _build_res(self, opt_state):
        import jax
        import jax.numpy as jnp

        def fn(state):
            norms, total_sq, total_n = {}, 0.0, 0
            for key in ("res_params", "res_b2"):
                if key not in state:
                    continue
                plane_tag = "params" if key == "res_params" else "b2"
                for name, flat in self._buckets(state[key]):
                    sq = jnp.sum(jnp.square(flat))
                    norms[(plane_tag, name)] = jnp.sqrt(sq)
                    total_sq = total_sq + sq
                    total_n += flat.size
            mse = (total_sq / total_n) if total_n else jnp.float32(0.0)
            return norms, mse

        return jax.jit(fn)

    # ------------------------------------------------------------------ #
    def step_summary(self, opt_state, metrics: Dict[str, Any], *,
                     synced: bool) -> Dict[str, Any]:
        """One step's health dict. ``metrics`` is the step's output-metrics
        map (device scalars fine — converted once here); residual probes
        run only when ``synced`` (the EF residual is rewritten exactly by
        sync rounds)."""
        out: Dict[str, Any] = {}
        if "grad_norm" in metrics:
            g = _np(metrics["grad_norm"]).reshape(-1)
            out["grad_norm"] = float(g.mean())
            if g.size > 1:
                out["grad_norm_per_worker"] = [float(v) for v in g]
        if "drift" in metrics:
            out["drift"] = float(_np(metrics["drift"]))
        has_state = isinstance(opt_state, dict)
        if has_state and "b2_local" in opt_state:
            if self._fn_b2 is None:
                self._fn_b2 = self._build_b2(opt_state)
            b2 = self._fn_b2(opt_state)
            out["b2"] = {name: {k: float(_np(v)) for k, v in d.items()}
                         for name, d in b2.items()}
        if synced and has_state and "res_params" in opt_state:
            if self._fn_res is None:
                self._fn_res = self._build_res(opt_state)
            norms, mse = self._fn_res(opt_state)
            out["ef_residual_norm"] = {
                f"{plane}/{name}": float(_np(v))
                for (plane, name), v in norms.items()}
            out["quant_mse"] = float(_np(mse))
        return out

    # ------------------------------------------------------------------ #
    def record(self, registry, summary: Dict[str, Any], *,
               step: int, synced: bool) -> None:
        """Feed one step's summary into a metrics registry (labeled gauges;
        grad-norm additionally per worker)."""
        if not registry:
            return
        if "grad_norm" in summary:
            registry.gauge("grad_norm",
                           help="L2 of raw grads, mean over workers"
                           ).set(summary["grad_norm"])
        for w, v in enumerate(summary.get("grad_norm_per_worker", [])):
            registry.gauge("grad_norm", worker=w).set(v)
        if "drift" in summary:
            registry.gauge("drift",
                           help="adaptive policy drift statistic"
                           ).set(summary["drift"])
        for name, qs in summary.get("b2", {}).items():
            for q, v in qs.items():
                registry.gauge("b2", help="B2 accumulator quantiles",
                               bucket=name, q=q).set(v)
        for tag, v in summary.get("ef_residual_norm", {}).items():
            plane, _, bucket = tag.partition("/")
            registry.gauge("ef_residual_norm",
                           help="L2 of the EF residual after last sync",
                           plane=plane, bucket=bucket).set(v)
        if "quant_mse" in summary:
            registry.gauge("quant_mse",
                           help="mean squared wire error of last sync round"
                           ).set(summary["quant_mse"])
        if synced:
            registry.counter("sync_rounds_total").inc()
            registry.counter(
                "wire_bytes_total",
                help="cumulative sync wire bytes (modeled codec payload)"
            ).inc(self.static_summary()["round_wire_bytes"])
