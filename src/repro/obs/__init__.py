"""Observability: training-health metrics + bench-regression gating.

The paper's contribution is a measured trade — fewer/cheaper sync rounds
for a bounded loss delta — and this package watches the *health* side of
that trade, which traces alone cannot see:

  metrics.py   a lightweight per-worker metrics registry (counters /
               gauges / histograms) collected host-side once per step and
               exported as a JSONL stream + a Prometheus textfile
               (``--metrics`` on ``launch.train`` and ``launch.dryrun``).
               Zero overhead when disabled: the null registry's methods
               are no-ops and instrumented code never computes a value.
  health.py    the sync-health probes the registry collects: per-bucket
               error-feedback residual norms, quantization MSE of the wire
               codec, wire compression ratio, the adaptive policy's drift
               statistic, gradient norm and B² accumulator quantiles —
               all derived host-side from state the step already
               materializes (CADA's and Local SGD's convergence knobs).
  regress.py   the bench-regression detector: diffs freshly produced
               ``BENCH_*.json`` rows against committed baselines
               (``benchmarks/baselines/``) field-by-field with stated
               tolerances and exits nonzero — the CI perf-regression gate.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_REGISTRY)
from repro.obs.health import SyncHealthProbe

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NULL_REGISTRY", "SyncHealthProbe"]
