"""Lightweight metrics registry: counters / gauges / histograms.

Designed for the train loop's cadence: instruments *register* metrics once,
*record* device-derived scalars as they appear, and the loop *collects* the
registry exactly once per step into a JSONL row (one line per collection,
append-only — the streaming format log shippers tail) and, on request, a
Prometheus textfile (the node-exporter ``textfile collector`` contract:
atomically replaced, scraped whole).

Zero overhead when disabled: :data:`NULL_REGISTRY` (and any
``MetricsRegistry(enabled=False)``) hands every instrument the same no-op
metric object, ``collect`` returns immediately, and — the part that
actually matters for step time — call sites guard their host-side value
derivation with ``if registry:`` so a disabled registry never forces a
device sync or a quantile pass. The registry is host-side bookkeeping
only; it never appears inside a jitted program.

Identity: a metric is ``(name, sorted label pairs)``. The same name may
carry many label sets (``ef_residual_norm{bucket=float32}`` vs
``{bucket=bfloat16}``); kind collisions on one name raise.

Histograms keep exact count/sum/min/max plus a bounded reservoir of the
most recent observations for the exported quantiles (p50/p90/p99) — a
step-time distribution does not need more than the recent window, and the
bound keeps a million-step run's registry flat.
"""
from __future__ import annotations

import collections
import json
import math
import os
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NULL_REGISTRY", "prom_sanitize"]

#: quantiles the JSONL rows and the Prometheus summary both export.
QUANTILES = (0.5, 0.9, 0.99)

#: observations a histogram keeps for quantile estimation.
RESERVOIR = 1024


def _labels_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labels_suffix(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


def prom_sanitize(name: str) -> str:
    """A metric name Prometheus accepts: ``[a-zA-Z_:][a-zA-Z0-9_:]*``."""
    out = "".join(c if c.isalnum() or c in "_:" else "_" for c in name)
    if not out or not (out[0].isalpha() or out[0] in "_:"):
        out = "_" + out
    return out


def _prom_escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _finite(x: float) -> float:
    x = float(x)
    return x if math.isfinite(x) else float("nan")


class _Metric:
    """Base instrument; the shared no-op when its registry is disabled."""

    kind = "none"

    def __init__(self, name: str = "", key: Tuple = (), help: str = ""):
        self.name = name
        self.key = key
        self.help = help

    # every instrument answers the whole API so the null object can stand
    # in for any kind without isinstance checks at the call sites
    def inc(self, by: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


class Counter(_Metric):
    """Monotone accumulator (steps run, sync rounds, wire bytes moved)."""

    kind = "counter"

    def __init__(self, name: str, key: Tuple, help: str = ""):
        super().__init__(name, key, help)
        self.value = 0.0

    def inc(self, by: float = 1.0) -> None:
        if by < 0:
            raise ValueError(f"counter {self.name} cannot decrease (by={by})")
        self.value += by


class Gauge(_Metric):
    """Last-value instrument (loss, residual norm, compression ratio)."""

    kind = "gauge"

    def __init__(self, name: str, key: Tuple, help: str = ""):
        super().__init__(name, key, help)
        self.value = float("nan")

    def set(self, value: float) -> None:
        self.value = _finite(value)


class Histogram(_Metric):
    """Distribution instrument: exact count/sum/min/max, reservoir quantiles."""

    kind = "histogram"

    def __init__(self, name: str, key: Tuple, help: str = "",
                 reservoir: int = RESERVOIR):
        super().__init__(name, key, help)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._window = collections.deque(maxlen=reservoir)

    def observe(self, value: float) -> None:
        v = _finite(value)
        if math.isnan(v):
            return
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._window.append(v)

    def quantile(self, q: float) -> float:
        if not self._window:
            return float("nan")
        xs = sorted(self._window)
        i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        return xs[i]

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        out = {"count": self.count, "sum": self.sum,
               "min": self.min, "max": self.max}
        for q in QUANTILES:
            out[f"p{int(q * 100)}"] = self.quantile(q)
        return out


class MetricsRegistry:
    """Per-run metric store + JSONL/Prometheus exporters.

    ``bool(registry)`` is the enabled flag — instrumented code guards any
    host-side value computation with it, which is what makes the disabled
    path genuinely free (no device readback, no quantile pass, no dict
    churn; the no-op instrument is belt and braces on top).
    """

    def __init__(self, enabled: bool = True,
                 labels: Optional[Dict[str, Any]] = None) -> None:
        self.enabled = bool(enabled)
        self.labels = dict(labels or {})        # run-constant, exported once
        self._metrics: Dict[Tuple[str, Tuple], _Metric] = {}
        self._null = _Metric("<disabled>")
        self._jsonl = None
        self._jsonl_path = ""
        self._t0: Optional[float] = None
        self.rows: List[Dict[str, Any]] = []

    def __bool__(self) -> bool:
        return self.enabled

    # ---------------- instruments ---------------------------------------- #
    def _get(self, cls, name: str, help: str, labels: Dict[str, Any]):
        if not self.enabled:
            return self._null
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(name, key[1], help=help)
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", **labels) -> Histogram:
        return self._get(Histogram, name, help, labels)

    def set_many(self, values: Dict[str, float], **labels) -> None:
        """Gauge-set a flat ``{name: value}`` dict (one probe's output)."""
        for k, v in values.items():
            self.gauge(k, **labels).set(v)

    # ---------------- collection ----------------------------------------- #
    def now(self) -> float:
        t = time.perf_counter()
        if self._t0 is None:
            self._t0 = t
        return t - self._t0

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{name{labels}: value}`` of every scalar instrument plus a
        ``{name{labels}: summary}`` map of the histograms."""
        scalars: Dict[str, float] = {}
        hists: Dict[str, Dict[str, float]] = {}
        for (name, lkey), m in sorted(self._metrics.items()):
            tag = name + _labels_suffix(lkey)
            if isinstance(m, Histogram):
                hists[tag] = m.summary()
            else:
                scalars[tag] = m.value
        return {"metrics": scalars, "hists": hists}

    def collect(self, step: int) -> Dict[str, Any]:
        """One JSONL row: the registry's state after this step. Appends to
        ``rows`` and to the attached JSONL stream (flushed per line, so a
        crashed run keeps every completed step)."""
        if not self.enabled:
            return {}
        row = {"step": int(step), "t_s": round(self.now(), 6),
               **self.snapshot()}
        self.rows.append(row)
        if self._jsonl is not None:
            json.dump(_jsonable(row), self._jsonl)
            self._jsonl.write("\n")
            self._jsonl.flush()
        return row

    # ---------------- exporters ------------------------------------------ #
    def open_jsonl(self, path: str) -> None:
        if not self.enabled:
            return
        self._jsonl_path = path
        self._jsonl = open(path, "w")
        header = {"stream": "repro.obs.metrics", "labels": self.labels}
        json.dump(_jsonable(header), self._jsonl)
        self._jsonl.write("\n")
        self._jsonl.flush()

    def close(self) -> None:
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None

    def prom_text(self) -> str:
        """The registry as Prometheus text exposition format (v0.0.4)."""
        base_labels = {str(k): str(v) for k, v in self.labels.items()}
        by_name: Dict[str, List[_Metric]] = {}
        for (name, _), m in sorted(self._metrics.items()):
            by_name.setdefault(name, []).append(m)
        lines: List[str] = []

        def fmt(v: float) -> str:
            return "NaN" if math.isnan(v) else repr(float(v))

        def labelstr(key, extra=None) -> str:
            items = dict(base_labels)
            items.update({k: v for k, v in key})
            if extra:
                items.update(extra)
            if not items:
                return ""
            inner = ",".join(f'{prom_sanitize(k)}="{_prom_escape(v)}"'
                             for k, v in sorted(items.items()))
            return "{" + inner + "}"

        for name, ms in by_name.items():
            pname = prom_sanitize("repro_" + name)
            kind = ms[0].kind
            help_txt = next((m.help for m in ms if m.help), "")
            if help_txt:
                lines.append(f"# HELP {pname} {help_txt}")
            lines.append(f"# TYPE {pname} "
                         f"{'summary' if kind == 'histogram' else kind}")
            for m in ms:
                if isinstance(m, Histogram):
                    for q in QUANTILES:
                        lines.append(
                            f"{pname}{labelstr(m.key, {'quantile': str(q)})} "
                            f"{fmt(m.quantile(q))}")
                    lines.append(f"{pname}_sum{labelstr(m.key)} {fmt(m.sum)}")
                    lines.append(f"{pname}_count{labelstr(m.key)} {m.count}")
                else:
                    lines.append(f"{pname}{labelstr(m.key)} {fmt(m.value)}")
        return "\n".join(lines) + "\n"

    def write_prom(self, path: str) -> None:
        """Atomic replace — the node-exporter textfile-collector contract
        (a scrape must never see a half-written file)."""
        if not self.enabled:
            return
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.prom_text())
        os.replace(tmp, path)


def _jsonable(x: Any) -> Any:
    if isinstance(x, float) and not math.isfinite(x):
        return None                     # JSONL stays strict-RFC parseable
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


#: the shared disabled registry — instrument against it unconditionally,
#: pay nothing (see module docstring).
NULL_REGISTRY = MetricsRegistry(enabled=False)
