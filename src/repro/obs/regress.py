"""Bench-regression detector: fresh ``BENCH_*.json`` vs committed baselines.

The benchmarks emit two kinds of numbers: *modeled/structural* facts
(kernel-launch counts, collective counts, modeled wire/HBM bytes, padding
element counts, gate booleans) that must reproduce exactly on any machine,
and *measured* walls (``us_per_call``, ``ms_per_step``, ...) that do not.
CI previously only checked each bench's own internal gates — a change that
doubled the flat plane's launch count or silently broke the int8 payload
model would sail through as long as the run completed. This module diffs a
fresh bench JSON against the committed baseline row-by-row, field-by-field,
with STATED tolerances (the ``TOLERANCES`` table below), and exits nonzero
on any regression — the CI perf-regression gate.

Comparison policy, first match wins (field name patterns):

  skipped      machine-dependent timings and derived fractions
               (``*_s``, ``*_ms``, ``us_*``, walls, speedups, ratios,
               comm fractions, throughputs), file paths, notes, and the
               adaptive schedules' raw ``sync_steps`` lists;
  loss-like    ``final_loss`` / ``final_ppl`` / ``loss_delta*``:
               relative 2% (cross-platform float drift on a 100+-step
               simulated run);
  schedule     ``sync_count`` & friends and span/event counts: relative
               35% (an adaptive threshold-edge sync may flip on a
               different BLAS);
  default      everything else numeric is a MODELED quantity and must
               reproduce to relative 1e-6; booleans must match exactly.

Rows are matched by identity keys (``bench``, ``method``, ``mode``, ...);
a baseline row with no fresh counterpart is itself a regression (a bench
quietly dropping coverage), while extra fresh rows are fine (new benches
don't need a baseline to land).

  PYTHONPATH=src python -m repro.obs.regress \
      [--baselines benchmarks/baselines] [--fresh .] [--report out.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["compare_rows", "compare_files", "main", "TOLERANCES"]

#: row keys that identify a row (subset present in the row is used).
IDENTITY_KEYS = ("bench", "method", "mode", "policy", "codec", "variant",
                 "workers", "mesh", "H")

#: (label, matcher, relative tolerance | None=skip) — first match wins.
#: THE stated-tolerance table; tests pin its behaviour.
TOLERANCES: List[Tuple[str, Any, Optional[float]]] = [
    ("timing/derived (machine-dependent): skipped",
     lambda f: (f.endswith("_s") or f.endswith("_ms") or f.endswith("_us")
                or f.startswith("ms_per") or f.startswith("us_per")
                or "wall" in f or "speedup" in f or "throughput" in f
                or "epoch_hours" in f or "elapsed" in f
                or "comm_fraction" in f or f == "ratio"
                or "comm_us" in f),
     None),
    ("paths/notes/schedules: skipped",
     lambda f: f in ("trace", "chrome", "note", "sync_steps", "gate"),
     None),
    ("loss-like: 2% relative",
     lambda f: ("loss" in f or "ppl" in f), 0.02),
    ("schedule-dependent counts: 35% relative",
     lambda f: ("sync_count" in f or "sync_reduction" in f
                or "comm_reduction" in f or "mb_per_step" in f
                or f in ("n_spans", "n_events", "sync_gap_min",
                         "sync_gap_max")),
     0.35),
    ("modeled/structural: 1e-6 relative", lambda f: True, 1e-6),
]


def field_tolerance(field: str) -> Optional[float]:
    """Relative tolerance for ``field`` per ``TOLERANCES`` (None = skip)."""
    leaf = field.rsplit(".", 1)[-1]
    for _, match, tol in TOLERANCES:
        if match(leaf):
            return tol
    return None


def _identity(row: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    out = []
    for k in IDENTITY_KEYS:
        if k in row:
            v = row[k]
            out.append((k, json.dumps(v) if isinstance(v, (list, dict))
                        else str(v)))
    return tuple(out)


def _flatten(prefix: str, value: Any, out: Dict[str, Any]) -> None:
    if isinstance(value, dict):
        for k, v in value.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    else:
        out[prefix] = value


def _num_close(a: float, b: float, tol: float) -> bool:
    if math.isnan(a) and math.isnan(b):
        return True
    scale = max(abs(a), abs(b), 1e-12)
    return abs(a - b) <= tol * scale + 1e-12


def _compare_value(field: str, base: Any, fresh: Any,
                   tol: float) -> Optional[str]:
    """None when acceptable, else a human-readable reason."""
    if isinstance(base, bool) or isinstance(fresh, bool):
        if bool(base) != bool(fresh):
            return f"{field}: {base!r} -> {fresh!r} (boolean gate flipped)"
        return None
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        if not _num_close(float(base), float(fresh), tol):
            return (f"{field}: {base!r} -> {fresh!r} "
                    f"(> {tol:g} relative tolerance)")
        return None
    if isinstance(base, list) and isinstance(fresh, list):
        if not all(isinstance(v, (int, float, bool)) for v in base):
            return None                      # non-numeric list: skip
        if len(base) != len(fresh):
            return (f"{field}: length {len(base)} -> {len(fresh)}")
        for i, (a, b) in enumerate(zip(base, fresh)):
            r = _compare_value(f"{field}[{i}]", a, b, tol)
            if r:
                return r
        return None
    if isinstance(base, str):
        return None                          # strings only matter as identity
    if type(base) is not type(fresh):
        return f"{field}: type {type(base).__name__} -> {type(fresh).__name__}"
    return None


def compare_rows(baseline: Sequence[Dict[str, Any]],
                 fresh: Sequence[Dict[str, Any]],
                 file: str = "") -> List[Dict[str, Any]]:
    """All regressions of ``fresh`` vs ``baseline`` (empty = clean)."""
    fresh_by_id: Dict[Tuple, Dict[str, Any]] = {}
    for row in fresh:
        fresh_by_id.setdefault(_identity(row), row)
    failures: List[Dict[str, Any]] = []
    for row in baseline:
        ident = _identity(row)
        tag = ", ".join(f"{k}={v}" for k, v in ident) or "<no identity>"
        match = fresh_by_id.get(ident)
        if match is None:
            failures.append({"file": file, "row": tag,
                             "reason": "baseline row missing from fresh "
                                       "output (bench dropped coverage?)"})
            continue
        flat_b: Dict[str, Any] = {}
        flat_f: Dict[str, Any] = {}
        _flatten("", dict(row), flat_b)
        _flatten("", dict(match), flat_f)
        for fieldname, base_v in flat_b.items():
            tol = field_tolerance(fieldname)
            if tol is None or fieldname in dict(ident):
                continue
            if fieldname not in flat_f:
                failures.append({"file": file, "row": tag,
                                 "reason": f"{fieldname}: missing from "
                                           f"fresh row"})
                continue
            reason = _compare_value(fieldname, base_v, flat_f[fieldname], tol)
            if reason:
                failures.append({"file": file, "row": tag, "reason": reason})
    return failures


def compare_files(baseline_path: str, fresh_path: str) -> List[Dict[str, Any]]:
    name = os.path.basename(baseline_path)
    if not os.path.exists(fresh_path):
        return [{"file": name, "row": "", "reason":
                 f"fresh bench output {fresh_path} not found"}]
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    if not isinstance(baseline, list) or not isinstance(fresh, list):
        return [{"file": name, "row": "", "reason":
                 "bench JSON must be a list of row dicts"}]
    return compare_rows(baseline, fresh, file=name)


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--files", nargs="*", default=None,
                    help="restrict to these basenames (default: every "
                         "baseline present)")
    ap.add_argument("--report", default="",
                    help="write the failure report JSON here")
    ap.add_argument("--allow-missing", action="store_true",
                    help="a missing fresh file is a warning, not a failure "
                         "(for partial local runs)")
    args = ap.parse_args(argv)

    paths = sorted(glob.glob(os.path.join(args.baselines, "BENCH_*.json")))
    if args.files:
        keep = set(args.files)
        paths = [p for p in paths if os.path.basename(p) in keep]
    if not paths:
        raise SystemExit(f"no baselines found under {args.baselines}")

    all_failures: List[Dict[str, Any]] = []
    checked = 0
    for bpath in paths:
        name = os.path.basename(bpath)
        fpath = os.path.join(args.fresh, name)
        if args.allow_missing and not os.path.exists(fpath):
            print(f"[regress] {name}: fresh output missing, skipped")
            continue
        fails = compare_files(bpath, fpath)
        checked += 1
        if fails:
            print(f"[regress] {name}: {len(fails)} regression(s)")
            for f in fails:
                print(f"  - {f['row']}: {f['reason']}" if f["row"]
                      else f"  - {f['reason']}")
        else:
            print(f"[regress] {name}: ok")
        all_failures.extend(fails)

    if args.report:
        with open(args.report, "w") as f:
            json.dump({"checked_files": checked,
                       "failures": all_failures}, f, indent=1)
    if all_failures:
        print(f"BENCH REGRESSION GATE FAILED: {len(all_failures)} "
              f"regression(s) across {checked} file(s)")
        raise SystemExit(1)
    print(f"bench regression gate: {checked} file(s) clean")


if __name__ == "__main__":
    main()
