"""Unified model API: ``build_model(cfg)`` -> init / loss_fn / prefill / decode.

Families:
  dense / moe / hybrid / ssm : decoder-only LM over tokens
  vlm                        : decoder LM + cross-attn to stubbed patch embeds
  audio                      : encoder-decoder over stubbed frame embeds
  lstm                       : the paper's Big LSTM
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import lstm as lstm_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import init_dense, rms_norm
from repro.sharding.partition import constraint


def _compute_dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------- #
def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy in fp32. logits: (B,S,V), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# Beyond-paper (§Perf): vocab-shard-safe xent. take_along_axis on a
# model-sharded vocab axis makes GSPMD gather the full (B,S,V) f32 logits;
# the iota-compare form fuses into a single sharded reduction. The custom
# VJP emits the (softmax - onehot) cotangent in the LOGITS dtype (bf16), so
# the lm_head backward matmuls run at bf16 traffic instead of f32.
@jax.custom_vjp
def fused_softmax_xent(logits, labels):
    nll, _ = _fused_xent_fwd_impl(logits, labels)
    return nll


def _fused_xent_fwd_impl(logits, labels):
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    z = jnp.sum(ex, axis=-1)
    logz = jnp.log(z) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    onehot = (iota == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, x, 0.0), axis=-1)
    nll = jnp.mean(logz - gold)
    return nll, (m[..., 0], z)


def _fused_xent_fwd(logits, labels):
    nll, (m, z) = _fused_xent_fwd_impl(logits, labels)
    return nll, (logits, labels, m, z)


def _fused_xent_bwd(res, g):
    logits, labels, m, z = res
    x = logits.astype(jnp.float32)
    p = jnp.exp(x - m[..., None]) / z[..., None]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    onehot = (iota == labels[..., None]).astype(jnp.float32)
    n_tokens = labels.size
    dlogits = (g / n_tokens) * (p - onehot)
    return dlogits.astype(logits.dtype), None


fused_softmax_xent.defvjp(_fused_xent_fwd, _fused_xent_bwd)


@dataclasses.dataclass
class Model:
    cfg: Any
    init: Callable[[jax.Array], Dict]
    loss_fn: Callable[..., Any]          # (params, batch, rng=None) -> (loss, metrics)
    logits_fn: Callable[..., Any]        # (params, batch) -> logits
    prefill: Callable[..., Any]          # (params, batch) -> (logits, cache)
    decode_step: Callable[..., Any]      # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable[..., Any]       # (batch_size, cache_len, ctx_lens) -> cache


# --------------------------------------------------------------------------- #
# transformer families
# --------------------------------------------------------------------------- #
def _build_transformer(cfg) -> Model:
    dtype = _compute_dtype(cfg)

    def init(key):
        ks = jax.random.split(key, 6)
        params = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dtype),
            "blocks": tfm.init_stack(ks[1], cfg, dtype,
                                     encdec_dec=cfg.is_encdec),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if cfg.is_encdec:
            params["encoder"] = tfm.init_stack(ks[2], dataclasses.replace(
                cfg, n_layers=cfg.n_encoder_layers, cross_attn_every=0,
                n_experts=0, hybrid=False), dtype)
            params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = init_dense(ks[3], cfg.d_model, cfg.vocab_size,
                                           scale=0.02, dtype=dtype)
        return params

    def _encode(params, batch):
        frames = batch["audio_frames"].astype(dtype)          # (B,F,D) stub frontend
        pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])
        enc_cfg = dataclasses.replace(cfg, n_layers=cfg.n_encoder_layers,
                                      cross_attn_every=0, n_experts=0,
                                      hybrid=False)
        h, _, _ = tfm.apply_stack(params["encoder"], enc_cfg, frames, pos,
                                  ctx={"causal": False})
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _ctx(params, batch):
        if cfg.is_encdec:
            return {"cross_src": _encode(params, batch)}
        if cfg.cross_attn_every:
            return {"cross_src": batch["image_embeds"].astype(dtype)}
        return {}

    def _trunk(params, batch, *, window=0, collect_cache=False, remat="none"):
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(dtype)
        x = constraint(x, ("batch", "seq_sp", "embed"))
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        ctx = _ctx(params, batch)
        x, aux, caches = tfm.apply_stack(
            params["blocks"], cfg, x, pos, ctx, window=window,
            collect_cache=collect_cache, encdec_dec=cfg.is_encdec, remat=remat)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, caches

    def _head(params, x):
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = x @ w
        return constraint(logits, ("batch", "seq", "vocab"))

    def logits_fn(params, batch):
        x, _, _ = _trunk(params, batch)
        return _head(params, x)

    def loss_fn(params, batch, rng=None, remat: str = "none"):
        x, aux, _ = _trunk(params, batch, remat=remat)
        logits = _head(params, x)
        if getattr(cfg, "fused_xent", False) and "mask" not in batch:
            loss = fused_softmax_xent(logits, batch["labels"])
        else:
            loss = softmax_xent(logits, batch["labels"], batch.get("mask"))
        return loss + aux, {"xent": loss, "aux": aux}

    def prefill(params, batch, *, window: int = 0):
        x, _, caches = _trunk(params, batch, window=window, collect_cache=True)
        logits = _head(params, x[:, -1:])
        return logits, caches

    def init_cache(batch_size: int, cache_len: int, *, windowed: bool = False,
                   cross_len: int = 0):
        """Zero-initialized stacked decode cache (pre-allocated ring buffers)."""
        kinds = tfm.group_kinds(cfg)
        g = cfg.n_layers // len(kinds)
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        entries = []
        for kind in kinds:
            c: Dict[str, Any] = {}
            if kind in ("self_dense", "self_moe", "hybrid"):
                c["kv"] = (jnp.zeros((g, batch_size, cache_len, kv, hd), dtype),
                           jnp.zeros((g, batch_size, cache_len, kv, hd), dtype))
            if kind in ("ssm", "hybrid"):
                s, ct = ssm_mod.init_ssm_state(cfg, batch_size, dtype)
                c["ssm"] = (jnp.zeros((g,) + s.shape, s.dtype),
                            jnp.zeros((g,) + ct.shape, ct.dtype))
            if kind == "cross" or cfg.is_encdec:
                c["xkv"] = (jnp.zeros((g, batch_size, cross_len, kv, hd), dtype),
                            jnp.zeros((g, batch_size, cross_len, kv, hd), dtype))
            entries.append(c)
        return entries

    def decode_step(params, caches, token, pos, *, window: int = 0):
        """token: (B,1); pos: (B,). Returns (logits (B,1,V), caches)."""
        x = params["embed"][token].astype(dtype)
        kv_leaves = [v for e in caches for k, v in e.items() if k == "kv"]
        spec = attn_mod.KVCacheSpec(
            cache_len=kv_leaves[0][0].shape[2] if kv_leaves else 0,
            windowed=bool(window))
        x, caches = tfm.decode_stack(params["blocks"], cfg, x, pos, caches,
                                     spec=spec)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = _head(params, x)
        return logits, caches

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, logits_fn=logits_fn,
                 prefill=prefill, decode_step=decode_step, init_cache=init_cache)


# --------------------------------------------------------------------------- #
# LSTM family
# --------------------------------------------------------------------------- #
def _build_lstm(cfg) -> Model:
    dtype = _compute_dtype(cfg)

    def init(key):
        return lstm_mod.init_lstm(key, cfg, dtype)

    def logits_fn(params, batch):
        return lstm_mod.lstm_logits(params, batch["tokens"], cfg)

    def loss_fn(params, batch, rng=None, remat: str = "none"):
        logits = lstm_mod.lstm_logits(params, batch["tokens"], cfg, rng=rng,
                                      dropout_rate=0.1 if rng is not None else 0.0)
        loss = softmax_xent(logits, batch["labels"], batch.get("mask"))
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    def prefill(params, batch, *, window: int = 0):
        # Recurrent state built by running the sequence; cache = final state.
        # The softmax head runs ONCE on the final hidden state — computing
        # the 793k-vocab logits at every timestep made the 32k prefill
        # memory term 8,388s/step (caught by the §Roofline table).
        tokens = batch["tokens"]
        B = tokens.shape[0]
        state0 = lstm_mod.init_lstm_state(cfg, B, dtype)
        h0 = jnp.zeros((B, cfg.lstm_proj), dtype)

        def step(carry, tok):
            st, _ = carry
            h, st = lstm_mod.lstm_hidden_step(params, tok[:, None], st, cfg)
            return (st, h), None

        (state, h), _ = jax.lax.scan(step, (state0, h0), tokens.T)
        logits = (h @ params["head_w"] + params["head_b"])[:, None]
        return logits, state

    def init_cache(batch_size: int, cache_len: int, *, windowed: bool = False,
                   cross_len: int = 0):
        return lstm_mod.init_lstm_state(cfg, batch_size, dtype)

    def decode_step(params, caches, token, pos, *, window: int = 0):
        logits, caches = lstm_mod.lstm_decode_step(params, token, caches, cfg)
        return logits, caches

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, logits_fn=logits_fn,
                 prefill=prefill, decode_step=decode_step, init_cache=init_cache)


# --------------------------------------------------------------------------- #
def build_model(cfg) -> Model:
    if cfg.family == "lstm":
        return _build_lstm(cfg)
    return _build_transformer(cfg)
