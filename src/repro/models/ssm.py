"""Mamba-2 (SSD, state-space duality) mixer — chunked scan + O(1) decode.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk the
recurrence is evaluated in its dual "attention-like" quadratic form; across
chunks the (heads, head_dim, state) recurrent state is carried by
``lax.scan``. Decode is the plain recurrence — constant state, which is what
makes the ssm/hybrid archs run ``long_500k`` natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rms_norm


def init_ssm(key, cfg, dtype=jnp.float32):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * n + nh
    return {
        "in_proj": init_dense(ks[0], d, proj_out, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * n)) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "norm": jnp.ones((di,), dtype),
        "out_proj": init_dense(ks[2], di, d, dtype=dtype),
    }


def _split_proj(cfg, zxbcdt):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w):
    """Depthwise causal conv. xbc: (B,L,C); conv_w: (W,C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(w))
    return jax.nn.silu(out)


def ssm_forward(params, x, cfg, *, return_state: bool = False):
    """Full-sequence SSD. x: (B,L,D) with L % ssm_chunk == 0 (padded if not)."""
    b, L, _ = x.shape
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    c = cfg.ssm_chunk
    pad = (-L) % c
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, params["conv_w"])
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        xbc = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nz = Lp // c

    xs = xbc[..., :di].reshape(b, nz, c, nh, hd).astype(jnp.float32)
    Bm = xbc[..., di:di + n].reshape(b, nz, c, n).astype(jnp.float32)
    Cm = xbc[..., di + n:].reshape(b, nz, c, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,Lp,nh)
    dt = dt.reshape(b, nz, c, nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (nh,)
    dA = dt * A                                                    # (B,nz,c,nh)
    cum = jnp.cumsum(dA, axis=2)                                   # (B,nz,c,nh)

    xbar = xs * dt[..., None]                                      # (B,nz,c,nh,hd)
    if getattr(cfg, "ssm_pallas", False) and not return_state:
        # Fused Pallas chunk scan (forward-only: serving/prefill path; the
        # cross-chunk state stays in VMEM — see kernels/ssd_scan.py).
        from repro.kernels.ssd_scan import ssd_scan
        y = ssd_scan(xbar, Bm, Cm, dA, interpret=jax.default_backend() != "tpu")
        S_last = None
    else:
        tri = jnp.tril(jnp.ones((c, c), bool))
        # intra-chunk dual form
        CB = jnp.einsum("bzln,bzsn->bzls", Cm, Bm)                 # (B,nz,c,c)
        logdecay = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nz,l,s,nh)
        logdecay = jnp.where(tri[None, None, :, :, None], logdecay, -jnp.inf)
        M = CB[..., None] * jnp.exp(logdecay)
        y = jnp.einsum("bzlsh,bzshp->bzlhp", M, xbar)

        # chunk boundary states
        seg = jnp.exp(cum[:, :, -1:, :] - cum)                     # decay to chunk end
        chunk_states = jnp.einsum("bzsn,bzsh,bzshp->bzhnp", Bm, seg, xbar)
        chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nz,nh)

        def scan_fn(S, inp):
            st, dk = inp                                           # (B,nh,N,P), (B,nh)
            S_new = S * dk[..., None, None] + st
            return S_new, S                                        # emit state BEFORE chunk

        S0 = jnp.zeros((b, nh, n, hd), jnp.float32)
        S_last, S_before = jax.lax.scan(
            scan_fn, S0,
            (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
        S_before = S_before.transpose(1, 0, 2, 3, 4)               # (B,nz,nh,N,P)

        # inter-chunk contribution
        y = y + jnp.einsum("bzln,bzlh,bzhnp->bzlhp", Cm, jnp.exp(cum), S_before)
    y = y + params["D"].astype(jnp.float32)[None, None, None, :, None] * xs
    y = y.reshape(b, Lp, di)[:, :L]
    z = z[:, :L]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        conv_state = _conv_tail(x, params, cfg)
        return out, (S_last, conv_state)
    return out


def _conv_tail(x, params, cfg):
    """Last (W-1) pre-conv channel rows, for decode continuation."""
    w = params["conv_w"].shape[0]
    zxbcdt = x[:, -(w - 1):] @ params["in_proj"]
    _, xbc, _ = _split_proj(cfg, zxbcdt)
    pad = (w - 1) - xbc.shape[1]
    if pad > 0:
        xbc = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    return xbc


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    nh, n, hd = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    return (
        jnp.zeros((batch, nh, n, hd), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype),
    )


def ssm_decode_step(params, x, state, cfg):
    """One-token recurrence. x: (B,1,D); state: (S, conv_tail)."""
    S, conv_tail = state
    b = x.shape[0]
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ params["in_proj"]                           # (B, P)
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_tail, xbc_new[:, None]], axis=1)  # (B,W,C)
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, params["conv_w"]))
    new_tail = window[:, 1:]

    xs = xbc[:, :di].reshape(b, nh, hd).astype(jnp.float32)
    Bm = xbc[:, di:di + n].astype(jnp.float32)
    Cm = xbc[:, di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                           # (B,nh)
    S = S * dA[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bm, dt, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cm, S)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(b, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    return out, (S, new_tail)
