"""Grouped-query attention: RoPE, QKV bias, sliding window, KV cache, cross-attn.

Full-sequence attention is computed blockwise (flash-style online softmax via
``lax.scan`` over KV chunks) so that 32k-token prefill never materializes the
(S, S) score matrix. Decode (Sq == 1) takes the direct path over the cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_dense

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------------- #
def init_attention(key, cfg, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, h * hd, dtype=dtype),
        "wk": init_dense(ks[1], d, kv * hd, dtype=dtype),
        "wv": init_dense(ks[2], d, kv * hd, dtype=dtype),
        "wo": init_dense(ks[3], h * hd, d, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _project_qkv(params, xq, xkv, cfg):
    b, sq, _ = xq.shape
    skv = xkv.shape[1]
    q = xq @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


# --------------------------------------------------------------------------- #
# core attention math
# --------------------------------------------------------------------------- #
def _mask(pos_q, pos_kv, causal: bool, window: int, valid_kv=None):
    """(..., Sq, Skv) additive mask in fp32."""
    m = jnp.zeros(pos_q.shape[:-1] + (pos_q.shape[-1], pos_kv.shape[-1]), jnp.float32)
    pq = pos_q[..., :, None]
    pk = pos_kv[..., None, :]
    if causal:
        m = jnp.where(pk > pq, NEG_INF, m)
    if window:
        m = jnp.where(pq - pk >= window, NEG_INF, m)
    if valid_kv is not None:
        m = jnp.where(valid_kv[..., None, :], m, NEG_INF)
    return m


def direct_attention(q, k, v, pos_q, pos_kv, *, causal: bool, window: int = 0,
                     valid_kv=None):
    """Unblocked attention. q: (B,Sq,H,hd)  k,v: (B,Skv,KV,hd)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    scale = hd ** -0.5
    qg = q.reshape(b, sq, kvh, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkrh,bskh->bkrqs", qg, k.astype(jnp.float32)) * scale
    mask = _mask(pos_q, pos_kv, causal, window, valid_kv)       # (B?,Sq,Skv)
    scores = scores + mask[:, None, None] if mask.ndim == 3 else scores + mask
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskh->bqkrh", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def blockwise_attention(q, k, v, pos_q, pos_kv, *, causal: bool,
                        window: int = 0, kv_block: int = 1024,
                        bf16_probs: bool = False):
    """Flash-style online-softmax attention, scanning over KV chunks.

    Memory is O(Sq * kv_block) instead of O(Sq * Skv).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    if skv <= 2 * kv_block:
        return direct_attention(q, k, v, pos_q, pos_kv, causal=causal, window=window)
    rep = h // kvh
    scale = hd ** -0.5

    pad = (-skv) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_kv = jnp.pad(pos_kv, ((0, 0), (0, pad)), constant_values=2 ** 30)
    n_blocks = k.shape[1] // kv_block

    qg = (q.reshape(b, sq, kvh, rep, hd) * scale).astype(jnp.float32)
    kb = k.reshape(b, n_blocks, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    pb = pos_kv.reshape(b, n_blocks, kv_block).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry
        k_c, v_c, p_c = blk
        s = jnp.einsum("bqkrh,bskh->bkrqs", qg, k_c.astype(jnp.float32))
        msk = _mask(pos_q, p_c, causal, window)                  # (B,Sq,kvb)
        s = s + msk[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        if bf16_probs:
            # §Perf (cfg.attn_bf16_probs): probabilities ride to the PV
            # matmul in the value dtype — the block-stacked p residuals
            # saved for the scan backward halve; the f32 m/l accumulators
            # keep the softmax normalization exact.
            pv = jnp.einsum("bkrqs,bskh->bkrqh", p.astype(v_c.dtype), v_c,
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bkrqs,bskh->bkrqh", p, v_c.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, rep, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]                 # (b,kv,rep,sq,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# block-level API
# --------------------------------------------------------------------------- #
def _tp_pad_heads(q, k, v, cfg):
    """Beyond-paper (§Perf): make attention shard cleanly over the TP axis.

    GQA head counts that do not divide the mesh 'model' axis (qwen: 28 q /
    4 kv heads on 16-way TP) force GSPMD to contract over a sharded
    head_dim, emitting score-sized partial-sum all-reduces inside the KV
    scan (measured: 75% of the per-step collective bytes). Padding q to the
    next multiple of the TP size and repeating k/v to MHA layout makes the
    score einsum embarrassingly parallel over heads. Cost: h_pad/h extra
    attention FLOPs (32/28 = +14% of the attention term only).

    Returns (q, k, v, orig_h, padded?) with shapes (B,S,H_pad,hd) when
    padded (k/v repeated to H_pad as well).
    """
    from repro.sharding.partition import active_rules
    rules = active_rules()
    if rules is None or not getattr(cfg, "attn_tp_pad", False):
        return q, k, v, cfg.n_heads, False
    tp = rules.mesh.shape.get(rules.plan.tp_axis, 1)
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    if tp <= 1 or (h % tp == 0 and kvh % tp == 0):
        return q, k, v, h, False
    h_pad = -(-h // tp) * tp
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    if h_pad > h:
        pad = ((0, 0), (0, 0), (0, h_pad - h), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    from repro.sharding.partition import constraint
    q = constraint(q, ("batch", "seq", "heads_tp", None))
    k = constraint(k, ("batch", "seq", "heads_tp", None))
    v = constraint(v, ("batch", "seq", "heads_tp", None))
    return q, k, v, h, True


def self_attention(params, x, positions, cfg, *, window: int = 0,
                   causal: bool = True, kv_block: int = 1024):
    """Full-sequence self-attention; returns (out, (k, v)) for cache priming."""
    q, k, v = _project_qkv(params, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qa, ka, va, h, padded = _tp_pad_heads(q, k, v, cfg)
    bf16_p = getattr(cfg, "attn_bf16_probs", False)
    if getattr(cfg, "attn_remat", False):
        # flash-style backward: recompute per-block scores instead of saving
        # the stacked (S x kv_block) probability tensors for the bwd scan.
        attn_fn = jax.checkpoint(
            lambda *a: blockwise_attention(*a, causal=causal, window=window,
                                           kv_block=kv_block,
                                           bf16_probs=bf16_p))
        out = attn_fn(qa, ka, va, positions, positions)
    else:
        out = blockwise_attention(qa, ka, va, positions, positions,
                                  causal=causal, window=window,
                                  kv_block=kv_block, bf16_probs=bf16_p)
    if padded:
        out = out[:, :, :h, :]
    out = out.reshape(x.shape[0], x.shape[1], -1) @ params["wo"]
    return out, (k, v)


def cross_attention_cached(params, x, k, v, cfg):
    """Cross-attention with precomputed (cached) K/V. x: (B,Sq,D)."""
    b, sq, _ = x.shape
    q = x @ params["wq"]
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
    q = q.reshape(b, sq, cfg.n_heads, cfg.head_dim)
    pos_q = jnp.zeros((b, sq), jnp.int32)
    pos_kv = jnp.zeros((b, k.shape[1]), jnp.int32)
    out = direct_attention(q, k, v, pos_q, pos_kv, causal=False)
    return out.reshape(b, sq, -1) @ params["wo"]


def cross_attention_full(params, x, kv_src, cfg):
    """Cross-attention computing K/V from kv_src; returns (out, (k, v))."""
    b, sq, _ = x.shape
    q, k, v = _project_qkv(params, x, kv_src, cfg)
    pos_q = jnp.zeros((b, sq), jnp.int32)
    pos_kv = jnp.zeros((b, k.shape[1]), jnp.int32)
    out = blockwise_attention(q, k, v, pos_q, pos_kv, causal=False)
    out = out.reshape(b, sq, -1) @ params["wo"]
    return out, (k, v)


# --------------------------------------------------------------------------- #
# decode with KV cache
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class KVCacheSpec:
    """Self-attn cache layout: ring buffer of size cache_len.

    For full attention cache_len == max_seq; for sliding-window archs
    cache_len == window (bounded state => sub-quadratic long-context decode).
    """
    cache_len: int
    windowed: bool


def decode_self_attention(params, x, cache_k, cache_v, pos, cfg,
                          spec: KVCacheSpec):
    """One-token decode. x: (B,1,D); cache_k/v: (B,cache_len,KV,hd); pos: (B,).

    Returns (out, new_k, new_v).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(params, x, x, cfg)
    positions = pos[:, None]                                   # (B,1)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    slot = (pos % spec.cache_len) if spec.windowed else pos
    oh = jax.nn.one_hot(slot, spec.cache_len, dtype=k.dtype)   # (B,L)
    cache_k = cache_k * (1.0 - oh[:, :, None, None]) + oh[:, :, None, None] * k
    cache_v = cache_v * (1.0 - oh[:, :, None, None]) + oh[:, :, None, None] * v

    idx = jnp.arange(spec.cache_len)[None, :]
    if spec.windowed:
        # Entry j holds absolute position: reconstruct from ring layout.
        base = (pos[:, None] // spec.cache_len) * spec.cache_len
        pos_kv = jnp.where(idx <= (pos[:, None] % spec.cache_len), base + idx,
                           base - spec.cache_len + idx)
        valid = pos_kv >= 0
    else:
        pos_kv = idx * jnp.ones((b, 1), jnp.int32)
        valid = idx <= pos[:, None]
    out = direct_attention(q, cache_k, cache_v, positions, pos_kv,
                           causal=True, window=0, valid_kv=valid)
    out = out.reshape(b, 1, -1) @ params["wo"]
    return out, cache_k, cache_v
