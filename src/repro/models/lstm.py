"""Big LSTM (LSTM-2048-512) — the paper's own evaluation architecture.

2 projected-LSTM layers (Sak et al. LSTMP cell) over 512-dim word
embeddings, full-softmax head. Time dimension via ``lax.scan``; decode is the
single recurrent step (O(1) state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dropout, init_dense


def init_lstm(key, cfg, dtype=jnp.float32):
    h, p, v = cfg.d_model, cfg.lstm_proj, cfg.vocab_size
    ks = jax.random.split(key, 3 + cfg.n_layers)
    params = {
        "embed": (jax.random.normal(ks[0], (v, p)) * 0.05).astype(dtype),
        "head_w": init_dense(ks[1], p, v, dtype=dtype),
        "head_b": jnp.zeros((v,), dtype),
        "cells": [],
    }
    cells = []
    for i in range(cfg.n_layers):
        k = ks[3 + i]
        k1, k2 = jax.random.split(k)
        cells.append({
            "wx": init_dense(k1, p, 4 * h, dtype=dtype),   # input is proj-sized
            "wh": init_dense(k2, p, 4 * h, dtype=dtype),
            "b": jnp.zeros((4 * h,), dtype),
            "wp": init_dense(k, h, p, dtype=dtype),        # recurrent projection
        })
    params["cells"] = cells
    return params


def _cell(cell, x, h_proj, c):
    gates = x @ cell["wx"] + h_proj @ cell["wh"] + cell["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h @ cell["wp"], c


def init_lstm_state(cfg, batch, dtype=jnp.float32):
    return [
        (jnp.zeros((batch, cfg.lstm_proj), dtype),
         jnp.zeros((batch, cfg.d_model), dtype))
        for _ in range(cfg.n_layers)
    ]


def lstm_logits(params, tokens, cfg, *, rng=None, dropout_rate: float = 0.0):
    """tokens: (B,S) -> logits (B,S,V)."""
    b, s = tokens.shape
    x = params["embed"][tokens]                            # (B,S,P)
    deterministic = rng is None or dropout_rate == 0.0
    if not deterministic:
        rng_layers = jax.random.split(rng, cfg.n_layers + 1)
        x = dropout(rng_layers[-1], x, dropout_rate, False)

    state = init_lstm_state(cfg, b, x.dtype)

    xs = x.transpose(1, 0, 2)                              # (S,B,P)
    for li, cell in enumerate(params["cells"]):
        def step(carry, xt, cell=cell):
            hp, c = carry
            hp, c = _cell(cell, xt, hp, c)
            return (hp, c), hp
        _, ys = jax.lax.scan(step, state[li], xs)
        if not deterministic:
            ys = dropout(rng_layers[li], ys, dropout_rate, False)
        xs = ys + xs if li > 0 else ys                     # residual after first layer
    out = xs.transpose(1, 0, 2)                            # (B,S,P)
    return out @ params["head_w"] + params["head_b"]


def lstm_hidden_step(params, token, state, cfg):
    """One recurrent step WITHOUT the softmax head.

    token: (B,1) int32; state: list[(h_proj, c)] -> (h (B,P), state).
    """
    x = params["embed"][token[:, 0]]
    new_state = []
    h = x
    for li, cell in enumerate(params["cells"]):
        hp, c = _cell(cell, h, state[li][0], state[li][1])
        new_state.append((hp, c))
        h = hp + h if li > 0 else hp
    return h, new_state


def lstm_decode_step(params, token, state, cfg):
    """token: (B,1) int32; state: list[(h_proj, c)] -> (logits (B,1,V), state)."""
    h, new_state = lstm_hidden_step(params, token, state, cfg)
    logits = h @ params["head_w"] + params["head_b"]
    return logits[:, None], new_state
