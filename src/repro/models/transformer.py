"""Decoder stack: homogeneous groups of sub-layers scanned with ``lax.scan``.

Layer heterogeneity (MoE every k-th layer, cross-attn every k-th layer) is
expressed as a repeating *group* of ``period`` sub-layers; parameters are
stacked over ``n_groups`` so the whole stack lowers to one rolled loop —
keeping HLO small enough that 512-device dry-run compiles stay fast even for
the 126-layer llama3-405b.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.counting import layer_kinds
from repro.models.layers import init_mlp, mlp_apply, rms_norm
from repro.sharding.partition import constraint


def group_period(cfg) -> int:
    if cfg.family == "ssm" or cfg.hybrid:
        return 1
    if cfg.cross_attn_every:
        return cfg.cross_attn_every
    if cfg.is_moe and cfg.moe_every > 1:
        return cfg.moe_every
    return 1


def group_kinds(cfg) -> List[str]:
    kinds = layer_kinds(cfg)
    p = group_period(cfg)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    group = kinds[:p]
    for g in range(cfg.n_layers // p):
        assert kinds[g * p:(g + 1) * p] == group, "layer pattern must repeat"
    return group


# --------------------------------------------------------------------------- #
# per-sub-layer init
# --------------------------------------------------------------------------- #
def _init_block(key, cfg, kind: str, dtype, *, encdec_dec: bool = False):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": jnp.ones((d,), dtype)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
        return p
    p["ln2"] = jnp.ones((d,), dtype)
    if kind == "self_dense":
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
        d_ff = cfg.dense_d_ff if (cfg.is_moe and cfg.moe_every > 1) else cfg.d_ff
        p["mlp"] = init_mlp(ks[1], d, d_ff, cfg.act, dtype)
    elif kind == "self_moe":
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    elif kind == "cross":
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)   # cross-attn weights
        p["mlp"] = init_mlp(ks[1], d, cfg.dense_d_ff or cfg.d_ff, cfg.act, dtype)
        p["gate"] = jnp.zeros((1,), dtype)                   # tanh-gated (llama3.2)
    elif kind == "hybrid":
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
        p["norm_attn"] = jnp.ones((d,), dtype)
        p["norm_ssm"] = jnp.ones((d,), dtype)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype)
    else:
        raise ValueError(kind)
    if encdec_dec:
        p["xattn"] = attn.init_attention(ks[3], cfg, dtype)
        p["ln3"] = jnp.ones((d,), dtype)
    return p


def init_stack(key, cfg, dtype, *, encdec_dec: bool = False) -> Dict[str, Any]:
    """Stacked params: one subtree per position-in-group, leading axis n_groups."""
    kinds = group_kinds(cfg)
    n_groups = cfg.n_layers // len(kinds)
    keys = jax.random.split(key, n_groups)

    def one_group(k):
        sub = jax.random.split(k, len(kinds))
        return [
            _init_block(sub[i], cfg, kinds[i], dtype, encdec_dec=encdec_dec)
            for i in range(len(kinds))
        ]

    groups = [one_group(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)


# --------------------------------------------------------------------------- #
# sub-layer application (full sequence)
# --------------------------------------------------------------------------- #
def _apply_block(bp, cfg, kind, x, positions, ctx, *, window: int,
                 collect_cache: bool, encdec_dec: bool = False):
    """Returns (x, aux_loss, cache_entry)."""
    cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if collect_cache:
            out, st = ssm_mod.ssm_forward(bp["ssm"], h, cfg, return_state=True)
            cache["ssm"] = st
        else:
            out = ssm_mod.ssm_forward(bp["ssm"], h, cfg)
        x = x + out
        # residual stream sharded over TP under seq_parallel (§Perf)
        x = constraint(x, ("batch", "seq_sp", "embed"))
        return x, aux, cache

    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind == "cross":
        img = ctx["cross_src"]
        out, kv = attn.cross_attention_full(bp["attn"], h, img, cfg)
        if collect_cache:
            cache["xkv"] = kv
        x = x + jnp.tanh(bp["gate"].astype(out.dtype)) * out
    elif kind == "hybrid":
        a_out, kv = attn.self_attention(bp["attn"], h, positions, cfg,
                                        window=window or cfg.sliding_window)
        s_out = ssm_mod.ssm_forward(bp["ssm"], h, cfg,
                                    return_state=collect_cache)
        if collect_cache:
            s_out, st = s_out
            cache["ssm"] = st
            cache["kv"] = kv
        a_out = rms_norm(a_out, bp["norm_attn"], cfg.norm_eps)
        s_out = rms_norm(s_out, bp["norm_ssm"], cfg.norm_eps)
        x = x + 0.5 * (a_out + s_out)
    else:  # self_dense / self_moe
        out, kv = attn.self_attention(bp["attn"], h, positions, cfg,
                                      window=window, causal=ctx.get("causal", True))
        if collect_cache:
            cache["kv"] = kv
        out = jax.ad_checkpoint.checkpoint_name(out, "attn_out")
        x = x + out
        x = constraint(x, ("batch", "seq_sp", "embed"))

    if encdec_dec:
        h = rms_norm(x, bp["ln3"], cfg.norm_eps)
        out, xkv = attn.cross_attention_full(bp["xattn"], h, ctx["cross_src"], cfg)
        if collect_cache:
            cache["xkv"] = xkv
        x = x + out

    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if kind == "self_moe":
        out, aux = moe_mod.moe_apply(bp["moe"], h, cfg)
    else:
        out = mlp_apply(bp["mlp"], h, cfg.act)
    out = jax.ad_checkpoint.checkpoint_name(out, "mlp_out")
    x = x + out
    x = constraint(x, ("batch", "seq_sp", "embed"))
    return x, aux, cache


def apply_stack(params, cfg, x, positions, ctx=None, *, window: int = 0,
                collect_cache: bool = False, encdec_dec: bool = False,
                remat: str = "none"):
    """Scan the stacked groups. Returns (x, aux_loss, caches|None)."""
    kinds = group_kinds(cfg)
    ctx = ctx or {}

    def group_fn(x, gp):
        aux_tot = jnp.zeros((), jnp.float32)
        caches = []
        for i, kind in enumerate(kinds):
            x, aux, cache = _apply_block(
                gp[i], cfg, kind, x, positions, ctx, window=window,
                collect_cache=collect_cache, encdec_dec=encdec_dec)
            aux_tot = aux_tot + aux
            caches.append(cache)
        return x, (aux_tot, caches)

    if remat == "full":
        group_fn = jax.checkpoint(group_fn)
    elif remat == "dots":
        group_fn = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    elif remat == "save_tp":
        # Beyond-paper (§Perf): recompute everything EXCEPT the sub-layer
        # outputs that sit just after the tensor-parallel partial-sum
        # all-reduces — replaying those in the backward pass would re-issue
        # the collectives (measured on qwen2-7b: ~1/3 of per-step AR bytes).
        group_fn = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out"))

    x, (aux, caches) = jax.lax.scan(group_fn, x, params)
    return x, jnp.sum(aux), (caches if collect_cache else None)


# --------------------------------------------------------------------------- #
# decode (one token, stacked caches)
# --------------------------------------------------------------------------- #
def _decode_block(bp, cfg, kind, x, pos, cache, ctx, spec):
    if kind == "ssm":
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        out, st = ssm_mod.ssm_decode_step(bp["ssm"], h, cache["ssm"], cfg)
        return x + out, {"ssm": st}
    new_cache: Dict[str, Any] = {}
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind == "cross":
        k, v = cache["xkv"]
        out = attn.cross_attention_cached(bp["attn"], h, k, v, cfg)
        new_cache["xkv"] = (k, v)
        x = x + jnp.tanh(bp["gate"].astype(out.dtype)) * out
    elif kind == "hybrid":
        ck, cv = cache["kv"]
        a_out, nk, nv = attn.decode_self_attention(bp["attn"], h, ck, cv, pos,
                                                   cfg, spec)
        s_out, st = ssm_mod.ssm_decode_step(bp["ssm"], h, cache["ssm"], cfg)
        new_cache["kv"] = (nk, nv)
        new_cache["ssm"] = st
        a_out = rms_norm(a_out, bp["norm_attn"], cfg.norm_eps)
        s_out = rms_norm(s_out, bp["norm_ssm"], cfg.norm_eps)
        x = x + 0.5 * (a_out + s_out)
    else:
        ck, cv = cache["kv"]
        out, nk, nv = attn.decode_self_attention(bp["attn"], h, ck, cv, pos,
                                                 cfg, spec)
        new_cache["kv"] = (nk, nv)
        x = x + out

    if "xkv" in cache and kind not in ("cross",):              # enc-dec decoder
        k, v = cache["xkv"]
        h = rms_norm(x, bp["ln3"], cfg.norm_eps)
        out = attn.cross_attention_cached(bp["xattn"], h, k, v, cfg)
        new_cache["xkv"] = (k, v)
        x = x + out

    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if kind == "self_moe":
        out, _ = moe_mod.moe_apply(bp["moe"], h, cfg)
    else:
        out = mlp_apply(bp["mlp"], h, cfg.act)
    return x + out, new_cache


def decode_stack(params, cfg, x, pos, caches, ctx=None, *,
                 spec: attn.KVCacheSpec):
    """x: (B,1,D); caches: stacked pytree (n_groups leading). Returns (x, caches)."""
    kinds = group_kinds(cfg)
    ctx = ctx or {}

    def group_fn(x, inp):
        gp, gcache = inp
        new_caches = []
        for i, kind in enumerate(kinds):
            x, nc = _decode_block(gp[i], cfg, kind, x, pos, gcache[i], ctx, spec)
            new_caches.append(nc)
        return x, new_caches

    x, new_caches = jax.lax.scan(group_fn, x, (params, caches))
    return x, new_caches
