"""Shared neural-net building blocks (pure functions over param pytrees)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def init_dense(key, d_in: int, d_out: int, scale: Optional[float] = None,
               dtype=jnp.float32):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def swiglu(x, w1, w3, w2):
    """SwiGLU MLP: (silu(x@w1) * (x@w3)) @ w2.

    w1: (D,F)  w3: (D,F)  w2: (F,D)
    """
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


def mlp_apply(params, x, act: str):
    if act == "swiglu":
        return swiglu(x, params["w1"], params["w3"], params["w2"])
    if act == "gelu":
        return gelu_mlp(x, params["w1"], params["w2"])
    return jax.nn.relu(x @ params["w1"]) @ params["w2"]


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w1": init_dense(k1, d_model, d_ff, dtype=dtype),
            "w3": init_dense(k2, d_model, d_ff, dtype=dtype),
            "w2": init_dense(k3, d_ff, d_model, dtype=dtype),
        }
    return {
        "w1": init_dense(k1, d_model, d_ff, dtype=dtype),
        "w2": init_dense(k2, d_ff, d_model, dtype=dtype),
    }


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dropout(key, x, rate: float, deterministic: bool):
    if deterministic or rate == 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), jnp.zeros_like(x))
