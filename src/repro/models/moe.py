"""Mixture-of-Experts FFN: top-k router, capacity, einsum dispatch (GShard-style).

Expert weights live on a leading expert axis which the sharding rules map to
the ``model`` mesh axis (expert parallelism); GSPMD lowers the dispatch /
combine einsums into the all-to-all-like collective schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, init_mlp, mlp_apply
from repro.sharding.partition import constraint


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], d, e, scale=0.02, dtype=jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, f)) * (1.0 / d) ** 0.5).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d, f)) * (1.0 / d) ** 0.5).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / f) ** 0.5).astype(dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], d, cfg.dense_d_ff, cfg.act, dtype=dtype)
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    cap = int(n_tokens * top_k * factor / n_experts)
    return max(cap, 4)


def moe_apply(params, x, cfg):
    """x: (B,S,D) -> (out, aux_loss)."""
    if getattr(cfg, "moe_group_tokens", False):
        return moe_apply_grouped(params, x, cfg)
    return moe_apply_einsum(params, x, cfg)


def _router(params, xt, cfg):
    """Shared top-k routing: returns (gate_vals, gate_idx, probs, pos, keep, cap)."""
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, e, k, cfg.capacity_factor)
    logits = xt.astype(jnp.float32) @ params["router"]             # (T,E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (T,k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)          # (T,k,E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - 1                             # (T*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, k)               # (T,k)
    keep = pos < cap
    return gate_vals * keep, gate_idx, probs, pos, keep, cap


def _expert_ffn(params, xin, cfg):
    """xin: (E,C,D) -> (E,C,D)."""
    h = jnp.einsum("ecd,edf->ecf", xin, params["w1"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xin, params["w3"])
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, params["w2"])             # (E,C,D)


def _aux_loss(probs, gate_idx, cfg):
    t, e = probs.shape[0], cfg.n_experts
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)            # (T,k,E)
    frac = jnp.mean(oh.sum(axis=1), axis=0)
    prob = jnp.mean(probs, axis=0)
    return cfg.router_aux_loss * e * jnp.sum(frac * prob)


def moe_apply_grouped(params, x, cfg):
    """Beyond-paper (§Perf, cfg.moe_group_tokens): gather/scatter dispatch.

    The GShard one-hot einsums cost 2·T·E·C·d FLOPs and materialize (T,E,C)
    f32 dispatch/combine tensors — at llama4 scale (E=128, T=65k/shard) that
    is ~17x the model's useful FLOPs (measured: useful ratio 0.058). Routing
    is fundamentally data movement, not matmul: build the (E·C) token index
    table with one scatter, gather expert inputs, and gather outputs back.
    FLOPs drop to the expert FFNs themselves; traffic to O((T·k + E·C)·d).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)
    gate_vals, gate_idx, probs, pos, keep, cap = _router(params, xt, cfg)

    # slot of each (token, choice) in the (E*C) expert buffer; dropped
    # tokens land in a sentinel slot that is sliced away.
    flat_slot = jnp.where(keep, gate_idx * cap + pos, e * cap)     # (T,k)
    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    buf_token = jnp.full((e * cap + 1,), t, jnp.int32)
    buf_token = buf_token.at[flat_slot.reshape(-1)].set(
        token_ids.reshape(-1).astype(jnp.int32), mode="drop")
    buf_token = buf_token[:e * cap]                                # (E*C,)

    # gather expert inputs (empty slots read token t -> filled with zeros)
    xin = jnp.take(xt, buf_token, axis=0, mode="fill",
                   fill_value=0).reshape(e, cap, d)
    xin = constraint(xin, ("experts", "capacity", "embed"))
    eout = _expert_ffn(params, xin, cfg)                           # (E,C,D)

    # combine: gather each surviving (token, choice) slot back
    out_tk = jnp.take(eout.reshape(e * cap, d),
                      jnp.where(keep, flat_slot, 0), axis=0)       # (T,k,D)
    out = jnp.sum(out_tk.astype(jnp.float32)
                  * gate_vals[..., None], axis=1)
    out = out.astype(x.dtype).reshape(b, s, d)
    if cfg.shared_expert:
        out = out + mlp_apply(params["shared"], x, cfg.act)
    return out, _aux_loss(probs, gate_idx, cfg)


def moe_apply_einsum(params, x, cfg):
    """GShard-style one-hot dispatch (paper-era baseline)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    cap = _capacity(t, e, k, cfg.capacity_factor)

    logits = (xt.astype(jnp.float32) @ params["router"])           # (T,E) fp32 router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (T,k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, choice) in its expert's buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)          # (T,k,E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - 1                             # (T*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, k)               # (T,k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch/combine tensors: (T,k,E) x (T,k,C) -> (T,E,C)
    oh_e = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)          # (T,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                          dtype=jnp.float32)                       # (T,k,C) (cap -> all-zero)
    dispatch = jnp.einsum("tke,tkc->tec", oh_e, oh_c)
    combine = jnp.einsum("tke,tkc,tk->tec", oh_e, oh_c, gate_vals)

    xin = jnp.einsum("td,tec->ecd", xt.astype(jnp.float32),
                     dispatch).astype(xt.dtype)                    # (E,C,D)
    h = jnp.einsum("ecd,edf->ecf", xin, params["w1"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xin, params["w3"])
    else:
        h = jax.nn.gelu(h)
    eout = jnp.einsum("ecf,efd->ecd", h, params["w2"])             # (E,C,D)
    out = jnp.einsum("ecd,tec->td", eout.astype(jnp.float32), combine)
    out = out.astype(x.dtype).reshape(b, s, d)

    if cfg.shared_expert:
        out = out + mlp_apply(params["shared"], x, cfg.act)

    # load-balance auxiliary loss (Shazeer/GShard form)
    frac = jnp.mean(oh_e.reshape(t, k, e).sum(axis=1), axis=0)     # tokens per expert
    prob = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_loss * e * jnp.sum(frac * prob)
    return out, aux
