"""Analytic parameter counts per architecture (used by rooflines).

These match the concrete pytrees produced by ``repro.models.model.init``
exactly; ``tests/test_models_smoke.py`` asserts the equality.
"""
from __future__ import annotations

from repro.configs import base as _base


def _attn_params(cfg: "_base.ModelConfig", cross: bool = False) -> int:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n = d * h * hd + 2 * d * kv * hd + h * hd * d          # wq, wk, wv, wo
    if cfg.qkv_bias:
        n += h * hd + 2 * kv * hd
    if cross:
        n += d                                              # extra q-norm? no: gate
    return n


def _mlp_params(cfg, d_ff: int) -> int:
    d = cfg.d_model
    if cfg.act == "swiglu":
        return 3 * d * d_ff
    return 2 * d * d_ff


def _ssm_params(cfg) -> int:
    d, di, n, hd = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    in_proj = d * (2 * di + 2 * n + nh)                     # z, x, B, C, dt
    conv = cfg.ssm_conv * (di + 2 * n)                      # depthwise conv over x,B,C
    other = nh + nh + nh                                    # A_log, D, dt_bias
    norm = di
    out = di * d
    return in_proj + conv + other + norm + out


def _moe_params(cfg) -> int:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    n = d * e                                               # router
    per_expert = 3 * d * f if cfg.act == "swiglu" else 2 * d * f
    n += e * per_expert
    if cfg.shared_expert:
        n += _mlp_params(cfg, cfg.dense_d_ff)
    return n


def _block_params(cfg, kind: str) -> int:
    d = cfg.d_model
    if kind == "ssm":
        return _ssm_params(cfg) + d                          # + pre-norm
    n = 0
    if kind in ("self_dense", "self_moe", "cross"):
        n += _attn_params(cfg) + 2 * d                       # attn + ln1 + ln2
        if kind == "self_moe":
            n += _moe_params(cfg)
        elif kind == "cross":
            n += _mlp_params(cfg, cfg.dense_d_ff or cfg.d_ff) + 1  # gate scalar
        else:
            n += _mlp_params(cfg, cfg.dense_d_ff if (cfg.is_moe and cfg.moe_every > 1) else cfg.d_ff)
    if kind == "hybrid":
        n += _attn_params(cfg) + _ssm_params(cfg) + 3 * d    # ln1 + ln2 + fuse norms... see model
        n += _mlp_params(cfg, cfg.d_ff)
    return n


def layer_kinds(cfg) -> list:
    """The per-layer kind sequence for the decoder stack."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            kinds.append("ssm")
        elif cfg.hybrid:
            kinds.append("hybrid")
        elif cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0:
            kinds.append("cross")
        elif cfg.is_moe and (i + 1) % cfg.moe_every == 0:
            kinds.append("self_moe")
        else:
            kinds.append("self_dense")
    return kinds


def count_params(cfg) -> int:
    if cfg.family == "lstm":
        e, h, p, v = cfg.lstm_proj, cfg.d_model, cfg.lstm_proj, cfg.vocab_size
        n = v * e                                            # embedding
        per = 4 * h * (e + p) + 4 * h + h * p                # LSTMP cell (in=proj size)
        n += cfg.n_layers * per
        n += p * v + v                                       # softmax
        return n

    n = cfg.vocab_size * cfg.d_model                         # embedding
    for kind in layer_kinds(cfg):
        n += _block_params(cfg, kind)
    if cfg.is_encdec:
        # encoder: self_dense blocks without causal mask + cross-attn in decoder
        n += cfg.n_encoder_layers * (_attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * cfg.d_model)
        n += cfg.n_layers * (_attn_params(cfg) + cfg.d_model)  # decoder cross-attn + ln
        n += cfg.d_model                                     # encoder final norm
    n += cfg.d_model                                         # final norm
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size                    # lm head
    return n


def count_active_params(cfg) -> int:
    """Per-token active parameters (MoE: top_k experts + shared)."""
    if not cfg.is_moe:
        return count_params(cfg)
    n = count_params(cfg)
    per_expert = (3 if cfg.act == "swiglu" else 2) * cfg.d_model * cfg.d_ff
    n_moe_layers = sum(1 for k in layer_kinds(cfg) if k == "self_moe")
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return n - inactive
