"""FlatSpace: the whole train state as one contiguous, tile-aligned plane.

The paper's wall-time win needs the *per-step* device cost to be small and
the *sync round* to be one cheap collective — but a per-leaf hot path pays
one kernel launch + one pad-to-tile per parameter leaf every step, and one
small collective per leaf every sync round. ``FlatSpace`` is the fix: at
init, every parameter-shaped pytree (params, B² accumulators, error-feedback
residuals, gradient anchors) is packed into ONE fp32 plane per state tensor,

  * **dtype-bucketed**: leaves are ordered so same-dtype leaves are
    contiguous (bf16 params never interleave with fp32 norms), and the
    per-row/per-block ``round16`` sidecars tell the flat kernels where wire
    and parameter values must round through bfloat16 — which is what keeps
    the flat plane *bitwise identical* to the per-leaf layout even though
    the plane itself is fp32 (an fp32 slot holds the bf16 value exactly;
    the kernels re-round after every write, so the next step reads the same
    bits the bf16 leaf would have held);
  * **tile-aligned**: each leaf's slot is padded to ``ALIGN`` (= one
    ``BLOCK_ROWS×128`` update-kernel grid tile) ONCE, at pack time — the
    per-leaf path pays the same pad-to-tile on every single launch;
  * **cheap to view**: ``unpack`` is a slice + reshape + cast per leaf, so
    the model forward consumes ordinary pytrees while the optimizer and the
    sync round run over the plane.

With the planes in place, the fused Local AdaAlter step is one
``pallas_call`` over the whole plane (``kernels.adaalter_update.
flat_fused_update``) instead of L launches, and the error-feedback sync
encode is one kernel plus ONE all-reduce of a single flat wire array
(``kernels.sync_fused.flat_ef_plane`` + :func:`mean_planes`) instead of
2·L small collectives. ``launch/steps.py`` routes both through here under
``OptimizerConfig.flat``.

Invariant the bitwise guarantees lean on: slot padding is zero and *stays*
zero — gradients pack to zero pads, so the update writes
``0 − η·0·rsqrt(B² + t'·ε²) = 0`` back (ε > 0, the paper's setting, keeps
the rsqrt finite on zero pads), and the sync kernel quantizes zero blocks
to zero wire + zero residual. Real elements therefore see exactly the
per-leaf values: slots are aligned to the quantization block, so wire
blocks never straddle leaves or workers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.adaalter_update import BLOCK_ROWS, LANES
from repro.kernels.tiling import padded_size

Pytree = Any

#: default slot alignment: one (BLOCK_ROWS, 128) update-kernel grid tile.
#: Divisible by every quantization block size in use (256 default), so the
#: sync-plane block partition matches the per-leaf one exactly.
ALIGN = BLOCK_ROWS * LANES

#: optimizer-state keys that are per-worker scalars, NOT param-shaped
#: subtrees (the same convention sharding/specs.opt_state_shardings uses).
SCALAR_STATE_KEYS = ("step", "tprime")


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """One leaf's home in the plane (offsets in elements, per batch row)."""

    index: int                 # position in the ORIGINAL tree flatten order
    shape: Tuple[int, ...]     # body shape (batch axes stripped)
    dtype: Any                 # the leaf's true dtype (what unpack restores)
    size: int                  # prod(shape)
    offset: int                # start element within the plane
    padded: int                # slot length (size rounded up to align)


class FlatSpace:
    """Geometry of one packed parameter plane.

    Built once from an abstract (or live) pytree whose leaves all carry the
    same ``batch_ndim`` leading axes (the local-SGD worker axis). All
    parameter-shaped planes (params, b2, residuals, anchors) share this one
    geometry; only their element dtype semantics differ (``unpack`` casts to
    the slot dtypes for params, or to a forced dtype for fp32 state planes).
    """

    def __init__(self, treedef, slots: List[LeafSlot],
                 batch_shape: Tuple[int, ...], align: int,
                 shards: int = 1, eps: Optional[float] = None) -> None:
        if eps is not None and eps <= 0:
            raise ValueError(
                "FlatSpace requires eps > 0: zero slot padding only stays "
                "zero through the update because rsqrt(B² + t'·eps²) is "
                "finite on zero pads — with eps == 0 the pads would train "
                f"on garbage (got eps={eps!r})")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.treedef = treedef
        self.slots = slots                     # in PLANE order (dtype buckets)
        self.batch_shape = batch_shape
        self.batch_ndim = len(batch_shape)
        self.align = align
        self.shards = shards
        # Tail-pad ONLY: slot offsets are independent of the shard count, so
        # the same checkpointed plane reshards across mesh shapes by padding
        # or truncating zero tail elements. Each of the ``shards`` contiguous
        # sub-planes is then a whole number of update-kernel tiles, so every
        # shard boundary lands on a tile (and quantization-block) boundary.
        end = (slots[-1].offset + slots[-1].padded) if slots else 0
        self.plane_size = padded_size(end, shards * align) if end else 0
        self.shard_size = self.plane_size // shards if shards else 0

    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, tree: Pytree, *, batch_ndim: int = 0,
              align: int = ALIGN, shards: int = 1,
              eps: Optional[float] = None) -> "FlatSpace":
        """Lay out ``tree``'s leaves into dtype buckets of aligned slots.

        ``tree`` may be live arrays or ``ShapeDtypeStruct``s. Leaves are
        grouped by dtype (buckets ordered by dtype name, stable within a
        bucket) so each bucket is one contiguous plane range. With
        ``shards > 1`` the plane gains tail padding so it splits into
        ``shards`` equal tile-aligned sub-planes (slot offsets unchanged).
        """
        assert align % LANES == 0, align
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            raise ValueError("cannot build a FlatSpace over an empty tree")
        batch_shape = tuple(leaves[0].shape[:batch_ndim])
        slots: List[LeafSlot] = []
        order = sorted(range(len(leaves)),
                       key=lambda i: (jnp.dtype(leaves[i].dtype).name, i))
        offset = 0
        for i in order:
            leaf = leaves[i]
            if tuple(leaf.shape[:batch_ndim]) != batch_shape:
                raise ValueError(
                    f"leaf {i} batch axes {leaf.shape[:batch_ndim]} != "
                    f"{batch_shape}")
            dtype = jnp.dtype(leaf.dtype)
            if not jnp.issubdtype(dtype, jnp.floating):
                raise ValueError(f"non-float leaf dtype {dtype} unsupported")
            body = tuple(leaf.shape[batch_ndim:])
            size = int(np.prod(body, dtype=np.int64)) if body else 1
            padded = padded_size(size, align)
            slots.append(LeafSlot(index=i, shape=body, dtype=dtype,
                                  size=size, offset=offset, padded=padded))
            offset += padded
        return cls(treedef, slots, batch_shape, align, shards=shards,
                   eps=eps)

    # ------------------------------------------------------------------ #
    # pack / unpack
    # ------------------------------------------------------------------ #
    def pack(self, tree: Pytree):
        """tree -> fp32 plane of shape ``batch_shape + (plane_size,)``.

        Casts every leaf to fp32 (exact for bf16) and zero-pads each slot —
        the once-per-init pad the per-leaf path re-pays every launch.
        """
        leaves = self.treedef.flatten_up_to(tree)
        parts = []
        for slot in self.slots:
            leaf = leaves[slot.index]
            flat = leaf.astype(jnp.float32).reshape(
                self.batch_shape + (slot.size,))
            if slot.padded != slot.size:
                pad = [(0, 0)] * self.batch_ndim + \
                      [(0, slot.padded - slot.size)]
                flat = jnp.pad(flat, pad)
            parts.append(flat)
        plane = parts[0] if len(parts) == 1 else jnp.concatenate(parts, -1)
        tail = self.plane_size - plane.shape[-1]
        if tail:                               # shard-count tail padding
            plane = jnp.pad(plane, [(0, 0)] * self.batch_ndim + [(0, tail)])
        return plane

    def unpack(self, plane, *, dtype: Optional[Any] = None) -> Pytree:
        """plane -> pytree of leaf views (slice + reshape + cast per leaf).

        ``dtype=None`` restores each slot's true dtype (params semantics);
        a concrete dtype (e.g. fp32) overrides it for the accumulator /
        residual / anchor planes, which mirror the param geometry but are
        fp32 state regardless of the param dtypes.
        """
        leaves: List[Any] = [None] * len(self.slots)
        for slot in self.slots:
            seg = plane[..., slot.offset:slot.offset + slot.size]
            leaves[slot.index] = seg.reshape(
                self.batch_shape + slot.shape).astype(dtype or slot.dtype)
        return self.treedef.unflatten(leaves)

    # ------------------------------------------------------------------ #
    # sidecars for the flat kernels (numpy, built once at trace time)
    # ------------------------------------------------------------------ #
    def round16_elems(self) -> np.ndarray:
        """(plane_size,) bool: True where the slot's dtype is 16-bit — the
        elements whose wire/parameter writes must round through bfloat16 to
        stay bitwise identical to the per-leaf layout."""
        mask = np.zeros(self.plane_size, np.bool_)
        for slot in self.slots:
            if jnp.dtype(slot.dtype).itemsize == 2:
                mask[slot.offset:slot.offset + slot.padded] = True
        return mask

    @staticmethod
    def rows_sidecar(elems: np.ndarray, row: int) -> np.ndarray:
        """Per-row (n_rows, 1) fp32 sidecar from a per-element mask; every
        ``row``-element run must be constant (guaranteed by slot alignment,
        since ``row`` divides ``align``)."""
        rows = elems.reshape(-1, row)
        assert (rows == rows[:, :1]).all(), "mask not constant per row"
        return rows[:, :1].astype(np.float32)

    # ------------------------------------------------------------------ #
    # accounting (the bench / dry-run numbers)
    # ------------------------------------------------------------------ #
    @property
    def n_leaves(self) -> int:
        return len(self.slots)

    @property
    def n_real(self) -> int:
        return sum(s.size for s in self.slots)

    @property
    def pad_elems(self) -> int:
        """Padding paid ONCE by the plane (vs once per launch per leaf)."""
        return self.plane_size - self.n_real

    def bucket_ranges(self) -> List[Tuple[str, int, int]]:
        """Contiguous (dtype_name, start, stop) plane ranges, one per
        dtype bucket (the dtype-bucketed layout makes these few)."""
        out: List[Tuple[str, int, int]] = []
        for slot in self.slots:
            name = jnp.dtype(slot.dtype).name
            if out and out[-1][0] == name and out[-1][2] == slot.offset:
                out[-1] = (name, out[-1][1], slot.offset + slot.padded)
            else:
                out.append((name, slot.offset, slot.offset + slot.padded))
        return out


# --------------------------------------------------------------------------- #
# whole-train-state conversion (checkpoint round-trips, restore adapters)
# --------------------------------------------------------------------------- #
def pack_opt_state(fs: FlatSpace, state: Dict[str, Any]) -> Dict[str, Any]:
    """Legacy per-leaf optimizer state -> flat: every param-shaped subtree
    (b2_sync / b2_local / res_* / g_anchor) becomes one fp32 plane; the
    per-worker scalar counters pass through untouched."""
    return {k: (v if k in SCALAR_STATE_KEYS else fs.pack(v))
            for k, v in state.items()}


def unpack_opt_state(fs: FlatSpace, flat_state: Dict[str, Any]
                     ) -> Dict[str, Any]:
    """Inverse of :func:`pack_opt_state`: planes -> fp32 per-leaf subtrees."""
    return {k: (v if k in SCALAR_STATE_KEYS
                else fs.unpack(v, dtype=jnp.float32))
            for k, v in flat_state.items()}


def flat_abstract(fs: FlatSpace, abstract_params: Pytree,
                  abstract_state: Dict[str, Any]):
    """Abstract (ShapeDtypeStruct) flat train state matching what
    :func:`pack_opt_state` produces — the restore template for a
    flat-layout checkpoint."""
    plane = jax.ShapeDtypeStruct(fs.batch_shape + (fs.plane_size,),
                                 jnp.float32)
    del abstract_params  # geometry already captured by fs
    state = {k: (v if k in SCALAR_STATE_KEYS else plane)
             for k, v in abstract_state.items()}
    return plane, state


def adapt_flat_state(plane, flat_state: Dict[str, Any], *,
                     workers: int, plane_size: int):
    """Reshard a restored flat train state across mesh shapes (host-side).

    The plane layout is tail-pad-only (:class:`FlatSpace` with ``shards``),
    so a checkpoint written under one shard count restores under another by
    padding or truncating the trailing zero tail — slot offsets never move.
    Worker-count changes replicate rows (grow) or merge row groups (shrink:
    identical rows pass through exactly, so a grow→shrink round-trip is
    bit-exact; diverged rows fall back to the fp32 mean, the same merge a
    sync round would apply). Scalar counters (step/tprime) replicate on
    grow and take the group head on shrink.
    """
    def _cols(a):
        have = a.shape[-1]
        if have == plane_size:
            return a
        if have < plane_size:
            return np.pad(a, [(0, 0)] * (a.ndim - 1) +
                          [(0, plane_size - have)])
        tail = a[..., plane_size:]
        if np.any(tail):
            raise ValueError(
                f"cannot truncate flat plane {have} -> {plane_size}: "
                "dropped tail is not all-zero (checkpoint was written by an "
                "incompatible slot layout, not just a larger shard pad)")
        return np.ascontiguousarray(a[..., :plane_size])

    def _rows(a, scalar):
        have = a.shape[0]
        if have == workers:
            return a
        if workers % have == 0:
            return np.repeat(a, workers // have, axis=0)
        if have % workers == 0:
            g = a.reshape((workers, have // workers) + a.shape[1:])
            if scalar or bool((g == g[:, :1]).all()):
                return np.ascontiguousarray(g[:, 0])
            return g.mean(axis=1).astype(a.dtype)
        raise ValueError(
            f"cannot reshard {have} checkpointed workers onto {workers}: "
            "one count must divide the other")

    plane = _rows(_cols(np.asarray(plane)), scalar=False)
    state = {}
    for k, v in flat_state.items():
        v = np.asarray(v)
        if k in SCALAR_STATE_KEYS:
            state[k] = _rows(v, scalar=True)
        else:
            state[k] = _rows(_cols(v), scalar=False)
    return plane, state


def is_flat_checkpoint(keys) -> bool:
    """Whether a checkpoint's flat leaf keys (checkpoint/store.py manifest)
    describe the packed-plane layout: params are ONE array (bare '#0' key)
    instead of a subtree ('#0/...')."""
    return any(k == "#0" for k in keys)


# --------------------------------------------------------------------------- #
# the single-collective sync mean
# --------------------------------------------------------------------------- #
def mean_planes(plane, round16_elems):
    """Cross-worker mean of one wire plane — the ONE collective of a flat
    sync round — bitwise identical to the per-leaf means.

    The mean accumulates in fp32 (exactly what ``jnp.mean`` does for a bf16
    leaf too: it upcasts, accumulates, and rounds the quotient back — pinned
    by tests/test_flat_step.py), then re-rounds the 16-bit slots through
    bfloat16 so the plane keeps holding the exact bits the per-leaf bf16
    mean would have produced.
    """
    from repro.kernels.tiling import round_through_bf16

    m = jnp.broadcast_to(jnp.mean(plane, axis=0, keepdims=True), plane.shape)
    if round16_elems is None or not round16_elems.any():
        return m
    return jnp.where(jnp.asarray(round16_elems), round_through_bf16(m), m)
