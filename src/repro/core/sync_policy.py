"""Pluggable *when-to-sync* decision for local (communication-skipping) SGD.

The paper fixes the sync period at H (Alg. 2/4: average every H-th step).
This module makes that decision a first-class, host-side policy consulted by
``train_loop`` between compiled steps, so the schedule can instead react to
the training dynamics (CADA lineage — Chen et al. 2020, PAPERS.md):

  fixed_h    the paper's schedule: sync when ``(step+1) % H == 0``, anchored
             at global step 0 so a checkpoint restore into the middle of an
             H-window continues the *pre-restore* schedule bit-identically;
  adaptive   accumulate the cheap device-side divergence statistic the step
             functions emit (``metrics['drift']``: per-worker parameter-drift
             norm of the step, relative to the parameter norm) and trigger
             the sync round once the accumulated drift since the last sync
             crosses ``threshold`` — never before ``h_min`` local steps,
             always by ``h_max``.

Policies are pure host-side Python (no jax): the two step programs are
compiled once (static ``do_sync``) and the policy only picks which one runs
next. Every policy records the *measured* sync schedule (``sync_count`` /
``sync_steps``) so ``TrainResult`` reports what actually moved instead of
the static ``2P/H`` formula — which a mid-window restore silently violates.

Degenerate cases (tested): ``threshold=0`` syncs every ``h_min`` steps,
``threshold=inf`` every ``h_max``; ``h_min == h_max == H`` is fixed-H
regardless of drift.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

#: policy names accepted by OptimizerConfig.sync_policy / --sync-policy.
POLICY_NAMES = ("fixed_h", "adaptive")


class SyncPolicy:
    """Host-side sync schedule. Subclasses implement :meth:`want_sync`.

    Protocol (driven by ``launch.train.train_loop``):
      reset(start_step)          once before the loop (restore re-anchor);
      want_sync(step)            pick sync_step vs local_step for ``step``;
      observe(step, synced, metrics)
                                 after the step ran — feeds back the
                                 divergence stat and records the schedule.
    """

    name = "base"

    def __init__(self) -> None:
        self.sync_count = 0
        self.sync_steps: List[int] = []

    def reset(self, start_step: int = 0) -> None:
        self.sync_count = 0
        self.sync_steps = []

    def want_sync(self, step: int) -> bool:
        raise NotImplementedError

    def observe(self, step: int, synced: bool,
                metrics: Dict[str, float] | None = None) -> None:
        if synced:
            self.sync_count += 1
            self.sync_steps.append(step)

    def host_state(self) -> Tuple[int, float]:
        """(window position, drift accumulator) — the schedule-critical
        state a checkpoint must carry (``core.sync_engine.SyncState``).
        Stateless policies (fixed_h anchors on the global step) have none.
        """
        return 0, 0.0

    def load_host_state(self, since: int, drift: float) -> None:
        """Inverse of :meth:`host_state`; no-op for stateless policies."""


class FixedHPolicy(SyncPolicy):
    """The paper's schedule: sync on every H-th global step.

    Anchored to global step 0 (not the restore point), so restoring a
    checkpoint saved mid-window keeps the exact pre-restore schedule — the
    property the bit-identity tests pin down.
    """

    name = "fixed_h"

    def __init__(self, H: int) -> None:
        super().__init__()
        if H < 1:
            raise ValueError(f"H must be >= 1, got {H}")
        self.H = H

    def want_sync(self, step: int) -> bool:
        return (step + 1) % self.H == 0


class AdaptiveSyncPolicy(SyncPolicy):
    """CADA-style divergence-triggered sync, bounded by [h_min, h_max].

    The k-th local step since the last sync (k = 1, 2, ...) is a sync step
    iff ``k >= h_max`` or (``k >= h_min`` and the drift accumulated from the
    steps since the last sync ``>= threshold``). The drift of the step being
    decided is not yet known — the policy is consulted *before* the step
    runs — so the trigger always lags the statistic by one step, which is
    what keeps the decision free (no extra device round-trip).
    """

    name = "adaptive"

    def __init__(self, threshold: float, h_min: int = 1,
                 h_max: int = 16) -> None:
        super().__init__()
        if h_min < 1:
            raise ValueError(f"h_min must be >= 1, got {h_min}")
        if h_max < h_min:
            raise ValueError(f"h_max ({h_max}) must be >= h_min ({h_min})")
        if threshold < 0 or math.isnan(threshold):
            raise ValueError(f"sync_threshold must be >= 0, got {threshold}")
        self.threshold = float(threshold)
        self.h_min = h_min
        self.h_max = h_max
        self._since = 0          # completed local steps since last sync
        self._drift = 0.0        # accumulated divergence since last sync

    def reset(self, start_step: int = 0) -> None:
        super().reset(start_step)
        # Without a restored SyncState the window re-anchors at the restore
        # point (conservative: at most h_max extra local steps vs the
        # uninterrupted run); ``load_host_state`` afterwards resumes the
        # exact pre-save window instead.
        self._since = 0
        self._drift = 0.0

    def host_state(self) -> Tuple[int, float]:
        return self._since, self._drift

    def load_host_state(self, since: int, drift: float) -> None:
        self._since = int(since)
        self._drift = float(drift)

    def want_sync(self, step: int) -> bool:
        k = self._since + 1
        if k >= self.h_max:
            return True
        if k < self.h_min:
            return False
        return self._drift >= self.threshold

    def observe(self, step: int, synced: bool,
                metrics: Dict[str, float] | None = None) -> None:
        super().observe(step, synced, metrics)
        if synced:
            self._since = 0
            self._drift = 0.0
        else:
            self._since += 1
            if metrics is not None:
                self._drift += float(metrics.get("drift", 0.0))


def make_sync_policy(cfg, *, is_local: bool = True, H: int = 0) -> SyncPolicy:
    """OptimizerConfig -> SyncPolicy.

    ``H`` overrides ``cfg.H`` (train_loop passes the resolved programs.H;
    synchronous optimizers get H=1 == sync every step). ``cfg.h_max == 0``
    defaults to ``4 * H`` so plain ``--sync-policy adaptive`` brackets the
    paper's period from both sides.
    """
    name = getattr(cfg, "sync_policy", "fixed_h") or "fixed_h"
    H = H or getattr(cfg, "H", 1)
    if name == "fixed_h":
        return FixedHPolicy(H)
    if name == "adaptive":
        if not is_local:
            raise ValueError(
                "sync_policy='adaptive' requires local-SGD execution: a "
                "local optimizer (local_sgd / local_adaalter) AND a "
                "parallelism plan with a worker axis (plan.local_axes). "
                "This run executes fully synchronously — gradients are "
                "all-reduced every step, so there is no sync to skip")
        h_max = getattr(cfg, "h_max", 0) or 4 * H
        return AdaptiveSyncPolicy(
            threshold=getattr(cfg, "sync_threshold", 0.0),
            h_min=max(1, getattr(cfg, "h_min", 1)),
            h_max=h_max)
    raise ValueError(f"unknown sync_policy {name!r} "
                     f"(expected one of {POLICY_NAMES})")
