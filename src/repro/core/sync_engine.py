"""The sync round as one owned subsystem: SyncEngine = policy + codec + kernel.

The paper's whole win is cheaper sync rounds, and three orthogonal pieces
decide what one round costs:

  *when*  a host-side :class:`~repro.core.sync_policy.SyncPolicy` (the
          paper's fixed every-H-steps schedule, or the CADA-style adaptive
          trigger fed by the drift statistic the compiled steps emit);
  *what*  a :class:`~repro.core.codecs.WireCodec` (fp32 / bf16 / int8+scales
          with error feedback);
  *how*   the device-side error-feedback encode — either the codec's fused
          one-HBM-pass kernel (``kernels/sync_fused.py``) or the generic
          three-pass encode/decode composition (:func:`ef_apply` picks).

:class:`SyncEngine` composes the three behind one object that
``launch.train.train_loop`` drives and the benchmarks/dry-run query for
accounting, so no call site hand-wires policy + codec + kernel again.

The engine also owns an explicit, pytree-serializable :class:`SyncState`
(the policy's schedule-critical host state: window position + drift
accumulator — kept as float64 numpy scalars so a checkpoint round-trip is
bit-exact against the host accumulation). ``checkpoint/store.py`` saves it
next to ``(params, opt_state)``; restoring it resumes the *exact* adaptive
schedule instead of re-anchoring the window at the restore point (the
error-feedback residuals, the other half of the sync state, already live in
the optimizer state as ``res_params``/``res_b2`` leaves and ride the normal
checkpoint path). fixed_h has no host state; its SyncState is zeros and the
restore is a no-op, preserving the bit-identity guarantees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import comm
from repro.core.codecs import WireCodec, get_codec
from repro.core.sync_policy import SyncPolicy, make_sync_policy

Pytree = Any

#: drift statistics the compiled local steps can emit for the adaptive
#: policy (configs.base.SyncConfig.drift_metric).
DRIFT_METRICS = ("update_norm", "grad_staleness")


#: policy names that consume ``metrics['drift']`` — the one condition
#: ``drift_statistic`` and :attr:`SyncEngine.wants_drift` both check.
_DRIFT_CONSUMERS = ("adaptive",)


def drift_statistic(sync_cfg) -> Optional[str]:
    """Which drift statistic the compiled steps must emit for this
    SyncConfig — ``None`` unless a drift-consuming policy is configured.
    The single source of truth ``launch.steps`` (emit the metric),
    ``core.optimizers`` (carry the gradient anchor) and
    :attr:`SyncEngine.wants_drift` (read it back) all share.
    """
    return (sync_cfg.drift_metric if sync_cfg.policy in _DRIFT_CONSUMERS
            else None)


# --------------------------------------------------------------------------- #
# checkpointable sync state
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class SyncState:
    """Schedule-critical host state of the sync policy, as a pytree.

    ``since``  completed local steps since the last sync (window position);
    ``drift``  drift accumulated over those steps (float64: bit-exact vs the
               host-side Python accumulation, so a restored run makes the
               same threshold comparisons as the uninterrupted one).
    """

    since: np.ndarray
    drift: np.ndarray

    @staticmethod
    def make(since: int = 0, drift: float = 0.0) -> "SyncState":
        return SyncState(since=np.asarray(since, np.int64),
                         drift=np.asarray(drift, np.float64))


jax.tree_util.register_dataclass(
    SyncState, data_fields=["since", "drift"], meta_fields=[])


# --------------------------------------------------------------------------- #
# device-side: error-feedback encode of one payload pytree
# --------------------------------------------------------------------------- #
def ef_apply(tree: Pytree, residual: Pytree, codec: WireCodec,
             batch_ndim: int, *, clamp_nonneg: bool = False
             ) -> Tuple[Pytree, Pytree]:
    """-> (wire values cast like ``tree``, new residual), per leaf:

        v     = x + e                       # fp32
        v̂     = codec.roundtrip(v)          # what the wire carries
        wire  = v̂ cast to x.dtype           # [clamped >= 0 for accumulators]
        e'    = v − wire

    When the codec provides a fused ``ef_roundtrip`` (int8 with
    ``SyncConfig.fused``), the whole chain runs in ONE HBM pass per leaf;
    otherwise it is composed from ``encode``/``decode`` (three passes over
    the payload). The two are bitwise identical (tests/test_sync_fused.py).
    Blocked codecs never let a block straddle the leading ``batch_ndim``
    (per-worker) axes.
    """
    flat_x, treedef = jax.tree_util.tree_flatten(tree)
    flat_e = treedef.flatten_up_to(residual)
    # Pin every payload leaf to its STORED dtype value before encoding.
    # XLA's excess-precision simplification may otherwise feed the encode an
    # unrounded fp32 view of a bf16 leaf (whatever the producing update
    # computed), making the wire/residual bits depend on fusion context —
    # a real multi-host wire materializes the bf16 buffer, and the flat
    # plane path (which re-rounds explicitly) must see the same bits.
    flat_x = [jax.lax.optimization_barrier(x) for x in flat_x]
    if codec.ef_roundtrip is not None:
        pairs = [codec.ef_roundtrip(x, e, min(batch_ndim, x.ndim),
                                    clamp_nonneg)
                 for x, e in zip(flat_x, flat_e)]
        return (treedef.unflatten([w for w, _ in pairs]),
                treedef.unflatten([r for _, r in pairs]))

    import jax.numpy as jnp
    # clamp_nonneg keeps accumulator payloads >= 0 (they feed rsqrt); for
    # plain payloads the value-preserving max against float32 min pins the
    # decoded wire value so the backend cannot contract the residual's
    # v − decode(...) into an FMA — the same guard the fused kernel uses,
    # keeping the two paths bitwise interchangeable (kernels/sync_fused.py).
    lower = 0.0 if clamp_nonneg else float(jnp.finfo(jnp.float32).min)
    wires, residuals = [], []
    for x, e in zip(flat_x, flat_e):
        v = x.astype(jnp.float32) + e
        vq = codec.roundtrip(v, min(batch_ndim, v.ndim))
        vq = jnp.maximum(vq, lower)
        # the barrier pins the wire cast the same way (excess precision
        # would otherwise let the residual subtract the unrounded value)
        w = jax.lax.optimization_barrier(vq.astype(x.dtype))
        wires.append(w)
        # residual vs what was ACTUALLY sent (incl. any bf16 wire cast)
        residuals.append(v - w.astype(jnp.float32))
    return treedef.unflatten(wires), treedef.unflatten(residuals)


# --------------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------------- #
class SyncEngine:
    """One object owning the sync round end-to-end.

    Host protocol (mirrors what ``train_loop`` used to hand-wire):
      reset(start_step) -> want_sync(step) -> [run step] -> observe(...)
    plus ``export_state()`` / ``import_state()`` around checkpoints, and
    the accounting queries the benchmarks/dry-run/TrainResult report.
    """

    def __init__(self, policy: SyncPolicy, codec: WireCodec, *,
                 algorithm: str = "local_adaalter", H: int = 1,
                 drift_metric: str = "update_norm",
                 block: int = 256) -> None:
        if drift_metric not in DRIFT_METRICS:
            raise ValueError(f"unknown drift_metric {drift_metric!r} "
                             f"(expected one of {DRIFT_METRICS})")
        self.policy = policy
        self.codec = codec
        self.algorithm = algorithm
        self.H = H
        self.drift_metric = drift_metric
        self.block = block

    # ---------------- schedule (delegates to the policy) ----------------- #
    def reset(self, start_step: int = 0) -> None:
        self.policy.reset(start_step)

    def want_sync(self, step: int) -> bool:
        return self.policy.want_sync(step)

    def observe(self, step: int, synced: bool,
                metrics: Optional[Dict[str, float]] = None) -> None:
        self.policy.observe(step, synced, metrics)

    @property
    def name(self) -> str:
        return self.policy.name

    @property
    def sync_count(self) -> int:
        return self.policy.sync_count

    @property
    def sync_steps(self) -> List[int]:
        return self.policy.sync_steps

    @property
    def wants_drift(self) -> bool:
        """Whether the compiled steps must emit ``metrics['drift']``."""
        return self.policy.name in _DRIFT_CONSUMERS

    # ---------------- checkpointable state -------------------------------- #
    def export_state(self) -> SyncState:
        since, drift = self.policy.host_state()
        return SyncState.make(since, drift)

    def import_state(self, state: SyncState) -> None:
        """Resume the exact schedule a checkpoint was saved under (call
        after :meth:`reset`; measured counters stay this-run-only)."""
        self.policy.load_host_state(int(np.asarray(state.since)),
                                    float(np.asarray(state.drift)))

    # ---------------- accounting ------------------------------------------ #
    def round_bytes(self, n_params: int) -> float:
        """Per-worker wire bytes of ONE sync round under this codec."""
        return comm.sync_payload_bytes(
            self.algorithm, n_params, compression=self.codec,
            block=self.block)

    def round_bytes_per_shard(self, n_params: int, n_shards: int = 1
                              ) -> float:
        """Per-DEVICE wire bytes of one sync round when the flat plane is
        FSDP/TP-sharded ``n_shards``-ways: each device all-reduces only its
        tile-aligned sub-plane across the worker axes, so the round moves
        ``round_bytes / n_shards`` per device (the full payload still
        crosses the fabric, but spread over the shard axis — this is the
        number the alpha-beta model and the trace/replay engine charge a
        device's collective with). ``n_shards == 1`` is :meth:`round_bytes`
        exactly."""
        return self.round_bytes(n_params) / max(1, int(n_shards))

    def modeled_bytes_per_step(self, n_params: int) -> float:
        """The static fixed-H formula (the paper's 2P/H claim)."""
        return comm.sync_bytes_per_step(
            self.algorithm, n_params, self.H, compression=self.codec,
            block=self.block)

    def grad_allreduce_bytes(self, n_params: int) -> float:
        """Per-step gradient all-reduce of fully synchronous execution —
        what moves when there is no sync round to skip."""
        return comm.payload_bytes(n_params)

    def encode_hbm_bytes(self, n_params: int, *,
                         fused: Optional[bool] = None) -> float:
        """Modeled device-side HBM traffic of one EF encode (see comm).

        The model describes the blocked int8 quantize pipeline; other
        codecs never run those passes, so asking is a caller bug, not a
        number to silently get wrong.
        """
        if self.codec.name != "int8":
            raise ValueError(
                f"ef_sync_hbm_bytes models the int8 quantize pipeline; "
                f"this engine's codec is {self.codec.name!r}")
        if fused is None:
            fused = self.codec.ef_roundtrip is not None
        return comm.ef_sync_hbm_bytes(
            int(n_params * comm.sync_round_multiplier(self.algorithm)),
            fused=fused, block=self.block)

    def round_collectives(self, n_payload_leaves: int, *,
                          flat: bool = False) -> int:
        """Collectives ONE sync round issues: the flat plane all-reduces a
        single packed wire array; the per-leaf path pays one all-reduce per
        payload leaf (x the algorithm's round multiplier). This is the
        ``n_collectives`` the alpha-beta fabric model charges latency for,
        and what the trace recorder stamps on ``collective`` spans."""
        return comm.round_collectives(self.algorithm, n_payload_leaves,
                                      flat=flat)

    def modeled_encode_hbm_bytes(self, n_params: int) -> float:
        """Modeled device-side HBM traffic of one sync round's EF encode,
        for ANY codec — the trace recorder's ``ef_encode`` span model
        (unlike :meth:`encode_hbm_bytes`, which answers only for the int8
        quantize pipeline it models exactly).

        int8  -> the fused/unfused pipeline model (``comm.ef_sync_hbm_bytes``)
        bf16  -> one EF pass over the payload: read x + residual, write the
                 re-rounded wire + new residual (fp32 master copies: 16n)
        fp32  -> 0 (lossless: no encode runs at all)
        """
        if self.codec.name == "int8":
            return self.encode_hbm_bytes(n_params)
        n = int(n_params * comm.sync_round_multiplier(self.algorithm))
        if self.codec.name == "bf16":
            return 16.0 * n
        return 0.0

    def __repr__(self) -> str:
        return (f"SyncEngine(policy={self.policy.name!r}, "
                f"codec={self.codec.name!r}, H={self.H}, "
                f"drift_metric={self.drift_metric!r}, "
                f"fused={self.codec.ef_roundtrip is not None})")


def make_sync_engine(opt_cfg, *, is_local: bool = True,
                     H: int = 0) -> SyncEngine:
    """OptimizerConfig (with its SyncConfig block) -> SyncEngine.

    ``H`` overrides ``cfg.H`` exactly like :func:`make_sync_policy` (the
    train loop passes the resolved ``programs.H``; synchronous execution
    gets H=1 == a round every step).
    """
    sync = opt_cfg.sync
    policy = make_sync_policy(opt_cfg, is_local=is_local, H=H)
    codec = get_codec(sync.compression, block=sync.block,
                      use_pallas=getattr(opt_cfg, "use_pallas", False),
                      fused=sync.fused)
    return SyncEngine(policy, codec, algorithm=opt_cfg.name,
                      H=H or opt_cfg.H, drift_metric=sync.drift_metric,
                      block=sync.block)
