"""Pluggable wire formats for the sync all-reduce payload.

The paper charges Local AdaAlter ``2P/H`` fp32 bytes per step (params +
accumulators every H-th step). *What* those bytes look like on the wire is
a codec choice, orthogonal to *when* they move (``core.sync_policy``):

  fp32   the paper's payload — 4 bytes/value, lossless;
  bf16   truncate the mantissa — 2 bytes/value (ROADMAP's 2x middle point),
         lossy but unbiased enough that error feedback recovers the rest;
  int8   per-block int8 + one fp32 scale per ``block`` values
         (``kernels/quantize.py``) — ~3.94x at block=256.

A :class:`WireCodec` is the single source of truth for both the *numerics*
(``encode``/``decode`` — what the receiver reconstructs) and the
*accounting* (``wire_bytes`` — what ``core.comm`` charges the fabric
model). ``core.optimizers.compressed_sync`` wraps any lossy codec with
error-feedback residuals; ``comm.payload_bytes`` dispatches here so the
modeled volume can never drift from the simulated wire format.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax.numpy as jnp

#: codec names accepted by OptimizerConfig.compression / --compress.
#: '' is an alias for 'fp32' (no compression wrapper at all).
CODEC_NAMES = ("fp32", "bf16", "int8")


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """One sync wire format: encode/decode numerics + byte accounting.

    encode(x, batch_ndim)        fp32 array -> opaque wire payload. Blocked
                                 codecs must not let a block straddle the
                                 leading ``batch_ndim`` (per-worker) axes.
    decode(payload, shape, batch_ndim)
                                 wire payload -> fp32 array of ``shape`` —
                                 exactly what the receiver reconstructs.
    wire_bytes(n_values, dtype_bytes)
                                 bytes this codec puts on the wire for one
                                 ``n_values``-element tensor.
    lossless                     True -> decode(encode(x)) == x bitwise, so
                                 error feedback is a no-op and
                                 ``compressed_sync`` skips the wrapper.
    """

    name: str
    lossless: bool
    encode: Callable[[Any, int], Any]
    decode: Callable[[Any, Tuple[int, ...], int], Any]
    wire_bytes: Callable[[int, int], float]
    #: optional ONE-HBM-PASS error-feedback encode
    #: ``(x, residual, batch_ndim, clamp_nonneg) -> (wire, new_residual)``
    #: fusing EF add + encode + decode + residual update (what the
    #: SyncEngine uses when the codec provides it); ``None`` -> the engine
    #: composes encode/decode in the generic three-pass way.
    ef_roundtrip: Optional[Callable[[Any, Any, int, bool],
                                    Tuple[Any, Any]]] = None

    def roundtrip(self, x, batch_ndim: int = 0):
        """decode(encode(x)) — the value the sync mean actually averages."""
        return self.decode(self.encode(x, batch_ndim), x.shape, batch_ndim)


def _fp32_codec() -> WireCodec:
    return WireCodec(
        name="fp32", lossless=True,
        encode=lambda x, bnd: x,
        decode=lambda p, shape, bnd: p,
        wire_bytes=lambda n, dtype_bytes=4: float(n * dtype_bytes))


def _bf16_codec() -> WireCodec:
    def encode(x, bnd):
        return x.astype(jnp.bfloat16)

    def decode(p, shape, bnd):
        return p.astype(jnp.float32)

    return WireCodec(
        name="bf16", lossless=False, encode=encode, decode=decode,
        wire_bytes=lambda n, dtype_bytes=4: float(n * 2))


def _int8_codec(block: int, use_pallas: bool, fused: bool) -> WireCodec:
    # kernel import stays inside the closures: pure accounting callers
    # (comm.payload_bytes) resolve the codec without touching Pallas

    def encode(x, bnd):
        from repro.kernels.quantize import quantize
        return quantize(x, block=block, batch_ndim=min(bnd, x.ndim),
                        use_pallas=use_pallas)

    def decode(payload, shape, bnd):
        from repro.kernels.quantize import dequantize
        q, scales = payload
        return dequantize(q, scales, shape, block=block,
                          batch_ndim=min(bnd, len(shape)),
                          use_pallas=use_pallas)

    def ef_roundtrip(x, e, bnd, clamp_nonneg):
        from repro.kernels.sync_fused import fused_ef_leaf
        return fused_ef_leaf(x, e, block=block, batch_ndim=bnd,
                             clamp_nonneg=clamp_nonneg,
                             use_pallas=use_pallas)

    return WireCodec(
        name="int8", lossless=False, encode=encode, decode=decode,
        wire_bytes=lambda n, dtype_bytes=4: n * (1.0 + 4.0 / block),
        ef_roundtrip=ef_roundtrip if fused else None)


def get_codec(name: str, *, block: int = 256, use_pallas: bool = False,
              fused: bool = True) -> WireCodec:
    """Resolve a codec name ('', 'fp32', 'bf16', 'int8') -> WireCodec.

    ``fused=False`` strips the codec's one-pass ``ef_roundtrip`` so the
    engine falls back to the three-pass composition (bench/debug knob; the
    two are bitwise identical).
    """
    if isinstance(name, WireCodec):
        return name
    if name in ("", "fp32"):
        return _fp32_codec()
    if name == "bf16":
        return _bf16_codec()
    if name == "int8":
        return _int8_codec(block, use_pallas, fused)
    raise ValueError(f"unknown compression {name!r} "
                     f"(expected one of {CODEC_NAMES})")
