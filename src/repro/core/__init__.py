"""The paper's primary contribution: AdaAlter / Local AdaAlter optimizers,
their synchronous baselines, the communication accounting, and the pluggable
sync subsystem (when to sync: ``sync_policy``; what goes on the wire:
``codecs``)."""
from repro.core.codecs import CODEC_NAMES, WireCodec, get_codec
from repro.core.optimizers import (
    LocalOptimizer,
    Optimizer,
    adaalter,
    adagrad,
    clip_by_global_norm,
    compressed_sync,
    global_norm,
    is_local,
    local_adaalter,
    local_sgd,
    make_optimizer,
    sgd,
    warmup_lr,
    with_grad_clip,
)
from repro.core.sync_policy import (
    POLICY_NAMES,
    AdaptiveSyncPolicy,
    FixedHPolicy,
    SyncPolicy,
    make_sync_policy,
)

__all__ = [
    "CODEC_NAMES",
    "POLICY_NAMES",
    "AdaptiveSyncPolicy",
    "FixedHPolicy",
    "LocalOptimizer",
    "Optimizer",
    "SyncPolicy",
    "WireCodec",
    "adaalter",
    "adagrad",
    "clip_by_global_norm",
    "compressed_sync",
    "get_codec",
    "global_norm",
    "is_local",
    "local_adaalter",
    "local_sgd",
    "make_optimizer",
    "make_sync_policy",
    "sgd",
    "warmup_lr",
    "with_grad_clip",
]
