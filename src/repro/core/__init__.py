"""The paper's primary contribution: AdaAlter / Local AdaAlter optimizers,
their synchronous baselines, and the communication accounting."""
from repro.core.optimizers import (
    LocalOptimizer,
    Optimizer,
    adaalter,
    adagrad,
    compressed_sync,
    is_local,
    local_adaalter,
    local_sgd,
    make_optimizer,
    sgd,
    warmup_lr,
)

__all__ = [
    "LocalOptimizer",
    "Optimizer",
    "adaalter",
    "adagrad",
    "compressed_sync",
    "is_local",
    "local_adaalter",
    "local_sgd",
    "make_optimizer",
    "sgd",
    "warmup_lr",
]
