"""The paper's primary contribution: AdaAlter / Local AdaAlter optimizers,
their synchronous baselines, the communication accounting, and the sync
subsystem owned end-to-end by ``sync_engine`` (when to sync:
``sync_policy``; what goes on the wire: ``codecs``; the fused device-side
encode: ``kernels/sync_fused``)."""
from repro.core.codecs import CODEC_NAMES, WireCodec, get_codec
from repro.core.optimizers import (
    LocalOptimizer,
    Optimizer,
    adaalter,
    adagrad,
    clip_by_global_norm,
    compressed_sync,
    global_norm,
    is_local,
    local_adaalter,
    local_sgd,
    make_optimizer,
    sgd,
    warmup_lr,
    with_grad_anchor,
    with_grad_clip,
)
from repro.core.sync_engine import (
    DRIFT_METRICS,
    SyncEngine,
    SyncState,
    ef_apply,
    make_sync_engine,
)
from repro.core.sync_policy import (
    POLICY_NAMES,
    AdaptiveSyncPolicy,
    FixedHPolicy,
    SyncPolicy,
    make_sync_policy,
)

__all__ = [
    "CODEC_NAMES",
    "DRIFT_METRICS",
    "POLICY_NAMES",
    "AdaptiveSyncPolicy",
    "FixedHPolicy",
    "LocalOptimizer",
    "Optimizer",
    "SyncEngine",
    "SyncPolicy",
    "SyncState",
    "WireCodec",
    "adaalter",
    "adagrad",
    "clip_by_global_norm",
    "compressed_sync",
    "ef_apply",
    "get_codec",
    "global_norm",
    "is_local",
    "local_adaalter",
    "local_sgd",
    "make_optimizer",
    "make_sync_engine",
    "make_sync_policy",
    "sgd",
    "warmup_lr",
    "with_grad_anchor",
    "with_grad_clip",
]
