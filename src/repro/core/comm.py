"""Communication accounting for the paper's Figure-1/2 claims.

Analytic per-step communication volume of each algorithm, plus the simple
latency/bandwidth time model used by the throughput benchmarks (the paper's
cluster is replaced by the TPU v5e constants from the roofline spec).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FabricModel:
    """Bandwidths in bytes/s; latency in s per collective round."""
    ici_bw: float = 50e9              # per-link ICI, v5e
    dcn_bw: float = 6.25e9            # cross-pod, per chip
    latency: float = 20e-6

    def scaled(self, bw_scale: float = 1.0,
               latency_scale: float = 1.0) -> "FabricModel":
        """A fabric with the bandwidths (and optionally latency) scaled —
        the trace replay's one-knob "slower interconnect" what-if
        (``repro.trace.replay.ReplayKnobs.bw_scale``)."""
        return dataclasses.replace(self, ici_bw=self.ici_bw * bw_scale,
                                   dcn_bw=self.dcn_bw * bw_scale,
                                   latency=self.latency * latency_scale)

    def allreduce_time(self, bytes_per_replica: float, n: int,
                       cross_pod: bool = False) -> float:
        """Ring all-reduce: 2*(n-1)/n * bytes over the slowest link —
        :meth:`collective_time` with a single collective."""
        return self.collective_time(bytes_per_replica, 1, n, cross_pod)

    def collective_time(self, n_bytes: float, n_collectives: int, n: int,
                        cross_pod: bool = False) -> float:
        """Alpha-beta model of one sync round issued as ``n_collectives``
        separate all-reduces totalling ``n_bytes`` per replica.

        alpha: every collective pays the full launch + rendezvous latency,
        so a per-leaf round pays it L times where the flat plane pays once;
        beta: the ring transfer term depends only on the TOTAL payload.
        This is the per-leaf vs flat gap the dry-run and
        ``benchmarks/bench_flat_step.py`` report:
        ``t = n_collectives·α + 2(n−1)/n · n_bytes / bw``.
        """
        if n <= 1 or n_collectives <= 0:
            return 0.0
        bw = self.dcn_bw if cross_pod else self.ici_bw
        return (n_collectives * self.latency
                + 2.0 * (n - 1) / n * n_bytes / bw)


def bytes_per_param(dtype_bytes: int = 4) -> int:
    return dtype_bytes


def payload_bytes(n_values: int, dtype_bytes: int = 4, compression: str = "",
                  block: int = 256) -> float:
    """Wire bytes for one synced tensor of ``n_values`` elements.

    Dispatches through :func:`repro.core.codecs.get_codec` so the accounting
    here can never drift from the wire format ``compressed_sync`` simulates:

    ''/'fp32' -> n · dtype_bytes (the paper's fp32 payload)
    'bf16'    -> n · 2 (the 2x middle point, no sidecar state)
    'int8'    -> n · 1 byte + one fp32 scale per ``block`` values
                 (= n · (1 + 4/block); ~3.94x less than fp32 at block=256)
    """
    from repro.core.codecs import get_codec
    return get_codec(compression, block=block).wire_bytes(
        n_values, dtype_bytes)


def ef_sync_hbm_bytes(n_values: int, *, fused: bool, dtype_bytes: int = 4,
                      block: int = 256) -> float:
    """Modeled device-side HBM traffic of ONE worker's error-feedback
    encode of an ``n_values``-element sync payload (int8 codec).

    fused (kernels/sync_fused.py — one pass):
        read  x (dtype_bytes·n) + residual (4n)
        write wire (dtype_bytes·n) + residual' (4n)
    unfused (the three-pass composition the fused kernel replaces):
        pass 1  EF add:        read x + e,        write v          (fp32)
        pass 2  quantize:      read v,            write q + scales
        pass 3  dequantize:    read q + scales,   write v̂
        residual update:       read v + v̂ [+ wire cast], write wire + e'
    The int8/scales intermediates (q: n bytes, scales: 4n/block) never
    touch HBM in the fused kernel — that and the v/v̂ round-trips are the
    ~2.4x traffic gap (38n vs 16n bytes at fp32)
    ``benchmarks/bench_sync_compression.py`` measures.
    """
    n = float(n_values)
    d = float(dtype_bytes)
    scales = 4.0 * n / block
    one_pass = (d * n + 4.0 * n) + (d * n + 4.0 * n)
    if fused:
        return one_pass
    q = 1.0 * n + scales
    return (
        (d * n + 4.0 * n) + 4.0 * n          # pass 1: read x,e  write v
        + (4.0 * n + q)                      # pass 2: read v    write q,s
        + (q + 4.0 * n)                      # pass 3: read q,s  write v̂
        + (4.0 * n + 4.0 * n)                # residual: read v, v̂
        + (d * n + 4.0 * n))                 #           write wire, e'


def collective_time(n_bytes: float, n_collectives: int, n_workers: int,
                    fabric: FabricModel = FabricModel(),
                    cross_pod: bool = False) -> float:
    """Module-level convenience for :meth:`FabricModel.collective_time` —
    launch/latency overhead of issuing one sync round as ``n_collectives``
    collectives (per-leaf: one per payload leaf; flat plane: one)."""
    return fabric.collective_time(n_bytes, n_collectives, n_workers,
                                  cross_pod)


def round_collectives(algorithm: str, n_payload_leaves: int,
                      flat: bool = False) -> int:
    """Collectives ONE sync round issues: the flat plane all-reduces a
    single packed wire array; the per-leaf path pays one all-reduce per
    payload leaf x the algorithm's round multiplier. The single source the
    SyncEngine, the dry-run record and the trace replay all share."""
    if flat:
        return 1
    return max(1, int(n_payload_leaves * sync_round_multiplier(algorithm)))


def sync_round_multiplier(algorithm: str) -> float:
    """How many param-sized tensors one communication round moves.

    AdaGrad/AdaAlter  : the gradient all-reduce               -> 1
    Local SGD         : params                                -> 1
    Local AdaAlter    : params + accumulators                 -> 2
    """
    if algorithm in ("sgd", "adagrad", "adaalter", "local_sgd"):
        return 1.0
    if algorithm == "local_adaalter":
        return 2.0
    raise ValueError(algorithm)


def sync_payload_bytes(algorithm: str, n_params: int, dtype_bytes: int = 4,
                       compression: str = "", block: int = 256) -> float:
    """Per-worker wire bytes of ONE communication round (one sync for local
    optimizers, one gradient all-reduce for synchronous ones). This is what
    ``train_loop`` multiplies by the policy's *measured* sync count."""
    return sync_round_multiplier(algorithm) * payload_bytes(
        n_params, dtype_bytes, compression, block)


def sync_bytes_per_step(algorithm: str, n_params: int, H: int = 1,
                        dtype_bytes: int = 4, compression: str = "",
                        block: int = 256) -> float:
    """MODELED average per-step communication volume per worker (bytes),
    assuming the fixed every-H-steps schedule.

    AdaGrad/AdaAlter  : gradient all-reduce every step        -> P
    Local SGD         : params every H steps                  -> P/H
    Local AdaAlter    : params + accumulators every H steps   -> 2P/H
                        (the paper's "2/H of fully synchronous" claim)

    ``compression`` rescales the payload (see :func:`payload_bytes`);
    with 'int8' Local AdaAlter moves ~P/2H instead of 2P/H. With an
    adaptive sync policy the schedule is data-dependent — use the measured
    ``TrainResult.comm_bytes_per_step`` instead of this formula.
    """
    per_round = sync_payload_bytes(algorithm, n_params, dtype_bytes,
                                   compression, block)
    if algorithm in ("sgd", "adagrad", "adaalter"):
        return per_round
    return per_round / H


def step_time(algorithm: str, n_params: int, compute_time: float, n_workers: int,
              H: int = 1, fabric: FabricModel = FabricModel(),
              cross_pod: bool = False, dtype_bytes: int = 4,
              compression: str = "", block: int = 256) -> float:
    """Paper Fig.1 model: step wall time = compute + (amortized) comm."""
    if algorithm == "none":
        return compute_time
    p = payload_bytes(n_params, dtype_bytes, compression, block)
    mult = sync_round_multiplier(algorithm)
    comm = mult * fabric.allreduce_time(p, n_workers, cross_pod)
    if algorithm in ("local_sgd", "local_adaalter"):
        comm /= H
    return compute_time + comm
