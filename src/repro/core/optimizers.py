"""The paper's algorithms as composable JAX optimizer transformations.

Algorithm 1  Distributed AdaGrad       -> :func:`adagrad`
Algorithm 2  Local SGD                 -> :func:`local_sgd`
Algorithm 3  Distributed AdaAlter      -> :func:`adaalter`
Algorithm 4  Local AdaAlter            -> :func:`local_adaalter`

Two-level API, mirroring the paper's structure:

* ``Optimizer`` (init/update) — the *fully synchronous* methods (Alg. 1 and 3),
  consuming the already-averaged gradient ``Ḡ_t`` (plus the averaged squared
  gradient ``(1/n)Σ Gᵢ∘Gᵢ`` that Alg. 3 accumulates).
* ``LocalOptimizer`` (init/local_step/sync) — the local methods (Alg. 2 and 4):
  ``local_step`` is applied per worker with NO communication; ``sync``
  averages parameters (and, for Local AdaAlter, the accumulated denominators)
  across workers — the only communication rounds.

All accumulators are fp32 regardless of parameter dtype.

Key AdaAlter invariants (tested in tests/test_adaalter.py):
  * the denominator used at local step t' after a sync is
    ``B²_sync + t'·ε²`` — identical on every worker (lazy ε²-placeholder);
  * AdaAlter updates params BEFORE folding G∘G into the accumulator;
  * ``local_adaalter`` with H=1 is bit-identical to ``adaalter``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _cast_like(x, ref):
    return x.astype(ref.dtype) if x.dtype != ref.dtype else x


def warmup_lr(base_lr: float, step, warmup_steps: int):
    """Paper §6.2.1: eta_t = eta * min(1, t / warm_up_steps)."""
    if warmup_steps <= 0:
        return jnp.asarray(base_lr, jnp.float32)
    t = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    return base_lr * jnp.minimum(1.0, t / warmup_steps)


# --------------------------------------------------------------------------- #
# fully synchronous optimizers (consume averaged gradients)
# --------------------------------------------------------------------------- #
class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    # update(grads, sq_grads, state, params) -> (new_params, new_state)
    # sq_grads is (1/n)sum_i G_i∘G_i; pass grads**2 when n == 1.
    update: Callable[..., Tuple[Pytree, Pytree]]


def sgd(lr: float = 0.1, warmup_steps: int = 0) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, sq_grads, state, params):
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - _cast_like(eta * g.astype(jnp.float32), p), params, grads)
        return new_params, {"step": step}

    return Optimizer(init, update)


def adagrad(lr: float = 0.5, eps: float = 1.0, b0: float = 0.0,
            warmup_steps: int = 0) -> Optimizer:
    """Algorithm 1. B²_t += Ḡ_t∘Ḡ_t  (mean gradient, squared), THEN
    x_t = x_{t-1} − η Ḡ_t/sqrt(B²_t + ε²·1).   B²_0 = b0²·1 (paper: 0)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "b2": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
        }

    def update(grads, sq_grads, state, params):
        del sq_grads  # Alg. 1 accumulates the square of the MEAN gradient
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        b2 = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state["b2"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: p - _cast_like(
                eta * g.astype(jnp.float32) / jnp.sqrt(a + eps * eps), p),
            params, grads, b2)
        return new_params, {"step": step, "b2": b2}

    return Optimizer(init, update)


def adaalter(lr: float = 0.5, eps: float = 1.0, b0: float = 1.0,
             warmup_steps: int = 0) -> Optimizer:
    """Algorithm 3. x_t = x_{t-1} − η Ḡ_t/sqrt(B²_{t-1} + ε²·1), THEN
    B²_t = B²_{t-1} + (1/n)Σᵢ Gᵢ,t∘Gᵢ,t.   B²_0 = b0²·1."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "b2": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
        }

    def update(grads, sq_grads, state, params):
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        # update params with the PREVIOUS accumulator + the eps^2 placeholder
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: p - _cast_like(
                eta * g.astype(jnp.float32) / jnp.sqrt(a + eps * eps), p),
            params, grads, state["b2"])
        # then fold the (worker-averaged) squared gradients in
        b2 = jax.tree_util.tree_map(
            lambda a, s: a + s.astype(jnp.float32), state["b2"], sq_grads)
        return new_params, {"step": step, "b2": b2}

    return Optimizer(init, update)


# --------------------------------------------------------------------------- #
# local (communication-skipping) optimizers
# --------------------------------------------------------------------------- #
class LocalOptimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    # local_step(grads, state, params) -> (new_params, new_state)   [no comm]
    local_step: Callable[..., Tuple[Pytree, Pytree]]
    # sync(params, state, mean_fn) -> (new_params, new_state)
    #   mean_fn: pytree -> pytree averaging across workers; identity if n == 1.
    sync: Callable[..., Tuple[Pytree, Pytree]]
    H: int


def _tree_mean_identity(tree):
    return tree


def local_sgd(lr: float = 0.1, H: int = 4, warmup_steps: int = 0) -> LocalOptimizer:
    """Algorithm 2: plain local SGD, params averaged every H steps."""

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def local_step(grads, state, params):
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - _cast_like(eta * g.astype(jnp.float32), p), params, grads)
        return new_params, {"step": step}

    def sync(params, state, mean_fn=_tree_mean_identity):
        return mean_fn(params), state

    return LocalOptimizer(init, local_step, sync, H)


def local_adaalter(lr: float = 0.5, eps: float = 1.0, b0: float = 1.0,
                   H: int = 4, warmup_steps: int = 0) -> LocalOptimizer:
    """Algorithm 4 — the paper's main contribution.

    State (per worker):
      b2_sync  : B²_{i,t-t'} — denominator base, ONLY updated at sync rounds,
                 hence identical on all workers at every local step.
      b2_local : A²_{i,t} — running local accumulation B²+Σ G∘G (averaged at sync).
      tprime   : number of local steps since the last sync (t' − 1 before the
                 current step).
      step     : global step count (for warm-up).

    local_step (Alg. 4 lines 4-9):
      t' = tprime + 1
      y  = x − η_t · G / sqrt(b2_sync + t'·ε²·1)
      b2_local += G∘G ;  tprime = t'

    sync (Alg. 4 lines 11-12, after the H-th local_step):
      x        <- mean_workers(x)
      b2_local <- mean_workers(b2_local)
      b2_sync  <- b2_local ;  tprime <- 0
    """

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "tprime": jnp.zeros((), jnp.int32),
            "b2_sync": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
            "b2_local": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
        }

    def local_step(grads, state, params):
        step = state["step"] + 1
        tprime = state["tprime"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        denom_extra = tprime.astype(jnp.float32) * (eps * eps)
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: p - _cast_like(
                eta * g.astype(jnp.float32) / jnp.sqrt(a + denom_extra), p),
            params, grads, state["b2_sync"])
        b2_local = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)),
            state["b2_local"], grads)
        return new_params, {"step": step, "tprime": tprime,
                            "b2_sync": state["b2_sync"], "b2_local": b2_local}

    def sync(params, state, mean_fn=_tree_mean_identity):
        new_params = mean_fn(params)
        b2 = mean_fn(state["b2_local"])
        return new_params, {"step": state["step"],
                            "tprime": jnp.zeros_like(state["tprime"]),
                            "b2_sync": b2, "b2_local": b2}

    return LocalOptimizer(init, local_step, sync, H)


# --------------------------------------------------------------------------- #
# factory
# --------------------------------------------------------------------------- #
def make_optimizer(cfg) -> Any:
    """cfg: OptimizerConfig -> Optimizer | LocalOptimizer."""
    if cfg.name == "sgd":
        return sgd(cfg.lr, cfg.warmup_steps)
    if cfg.name == "adagrad":
        return adagrad(cfg.lr, cfg.eps, cfg.b0, cfg.warmup_steps)
    if cfg.name == "adaalter":
        return adaalter(cfg.lr, cfg.eps, cfg.b0, cfg.warmup_steps)
    if cfg.name == "local_sgd":
        return local_sgd(cfg.lr, cfg.H, cfg.warmup_steps)
    if cfg.name == "local_adaalter":
        return local_adaalter(cfg.lr, cfg.eps, cfg.b0, cfg.H, cfg.warmup_steps)
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def is_local(opt) -> bool:
    return isinstance(opt, LocalOptimizer)
