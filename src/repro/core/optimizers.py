"""The paper's algorithms as composable JAX optimizer transformations.

Algorithm 1  Distributed AdaGrad       -> :func:`adagrad`
Algorithm 2  Local SGD                 -> :func:`local_sgd`
Algorithm 3  Distributed AdaAlter      -> :func:`adaalter`
Algorithm 4  Local AdaAlter            -> :func:`local_adaalter`

Two-level API, mirroring the paper's structure:

* ``Optimizer`` (init/update) — the *fully synchronous* methods (Alg. 1 and 3),
  consuming the already-averaged gradient ``Ḡ_t`` (plus the averaged squared
  gradient ``(1/n)Σ Gᵢ∘Gᵢ`` that Alg. 3 accumulates).
* ``LocalOptimizer`` (init/local_step/sync) — the local methods (Alg. 2 and 4):
  ``local_step`` is applied per worker with NO communication; ``sync``
  averages parameters (and, for Local AdaAlter, the accumulated denominators)
  across workers — the only communication rounds.

All accumulators are fp32 regardless of parameter dtype.

Key AdaAlter invariants (tested in tests/test_adaalter.py):
  * the denominator used at local step t' after a sync is
    ``B²_sync + t'·ε²`` — identical on every worker (lazy ε²-placeholder);
  * AdaAlter updates params BEFORE folding G∘G into the accumulator;
  * ``local_adaalter`` with H=1 is bit-identical to ``adaalter``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _cast_like(x, ref):
    return x.astype(ref.dtype) if x.dtype != ref.dtype else x


def warmup_lr(base_lr: float, step, warmup_steps: int):
    """Paper §6.2.1: eta_t = eta * min(1, t / warm_up_steps)."""
    if warmup_steps <= 0:
        return jnp.asarray(base_lr, jnp.float32)
    t = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    return base_lr * jnp.minimum(1.0, t / warmup_steps)


def global_norm(tree, batch_ndim: int = 0):
    """fp32 L2 norm over all leaves; with ``batch_ndim=1`` one norm per row
    of the leading (worker) axis, shape (R,)."""
    sq = [jnp.sum(jnp.square(g.astype(jnp.float32)),
                  axis=tuple(range(batch_ndim, g.ndim)))
          for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(sq))


def clip_by_global_norm(grads, max_norm: float, batch_ndim: int = 0):
    """Scale ``grads`` so their global L2 norm is <= ``max_norm``.

    Returns ``(clipped, factor)``; ``factor`` is 1 when no clipping fires
    (and the leaves pass through bitwise untouched dtype-wise: the scale is
    applied in fp32 and cast back). ``batch_ndim=1`` clips each worker's
    gradient independently (the stacked layout of the fused step path).
    ``max_norm <= 0`` disables clipping entirely.
    """
    if max_norm <= 0:
        return grads, jnp.float32(1.0)
    norm = global_norm(grads, batch_ndim)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-16))

    def scale(g):
        f = factor.reshape(factor.shape + (1,) * (g.ndim - batch_ndim))
        return (g.astype(jnp.float32) * f).astype(g.dtype)

    return jax.tree_util.tree_map(scale, grads), factor


# --------------------------------------------------------------------------- #
# fully synchronous optimizers (consume averaged gradients)
# --------------------------------------------------------------------------- #
class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    # update(grads, sq_grads, state, params) -> (new_params, new_state)
    # sq_grads is (1/n)sum_i G_i∘G_i; pass grads**2 when n == 1.
    update: Callable[..., Tuple[Pytree, Pytree]]


def sgd(lr: float = 0.1, warmup_steps: int = 0) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, sq_grads, state, params):
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - _cast_like(eta * g.astype(jnp.float32), p), params, grads)
        return new_params, {"step": step}

    return Optimizer(init, update)


def adagrad(lr: float = 0.5, eps: float = 1.0, b0: float = 0.0,
            warmup_steps: int = 0) -> Optimizer:
    """Algorithm 1. B²_t += Ḡ_t∘Ḡ_t  (mean gradient, squared), THEN
    x_t = x_{t-1} − η Ḡ_t/sqrt(B²_t + ε²·1).   B²_0 = b0²·1 (paper: 0)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "b2": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
        }

    def update(grads, sq_grads, state, params):
        del sq_grads  # Alg. 1 accumulates the square of the MEAN gradient
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        b2 = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state["b2"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: p - _cast_like(
                eta * g.astype(jnp.float32) / jnp.sqrt(a + eps * eps), p),
            params, grads, b2)
        return new_params, {"step": step, "b2": b2}

    return Optimizer(init, update)


def adaalter(lr: float = 0.5, eps: float = 1.0, b0: float = 1.0,
             warmup_steps: int = 0) -> Optimizer:
    """Algorithm 3. x_t = x_{t-1} − η Ḡ_t/sqrt(B²_{t-1} + ε²·1), THEN
    B²_t = B²_{t-1} + (1/n)Σᵢ Gᵢ,t∘Gᵢ,t.   B²_0 = b0²·1."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "b2": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
        }

    def update(grads, sq_grads, state, params):
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        # update params with the PREVIOUS accumulator + the eps^2 placeholder
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: p - _cast_like(
                eta * g.astype(jnp.float32) / jnp.sqrt(a + eps * eps), p),
            params, grads, state["b2"])
        # then fold the (worker-averaged) squared gradients in
        b2 = jax.tree_util.tree_map(
            lambda a, s: a + s.astype(jnp.float32), state["b2"], sq_grads)
        return new_params, {"step": step, "b2": b2}

    return Optimizer(init, update)


# --------------------------------------------------------------------------- #
# local (communication-skipping) optimizers
# --------------------------------------------------------------------------- #
class LocalOptimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    # local_step(grads, state, params) -> (new_params, new_state)   [no comm]
    local_step: Callable[..., Tuple[Pytree, Pytree]]
    # sync(params, state, mean_fn) -> (new_params, new_state)
    #   mean_fn: pytree -> pytree averaging across workers; identity if n == 1.
    sync: Callable[..., Tuple[Pytree, Pytree]]
    H: int


def _tree_mean_identity(tree):
    return tree


def local_sgd(lr: float = 0.1, H: int = 4, warmup_steps: int = 0) -> LocalOptimizer:
    """Algorithm 2: plain local SGD, params averaged every H steps."""

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def local_step(grads, state, params):
        step = state["step"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - _cast_like(eta * g.astype(jnp.float32), p), params, grads)
        return new_params, {"step": step}

    def sync(params, state, mean_fn=_tree_mean_identity):
        return mean_fn(params), state

    return LocalOptimizer(init, local_step, sync, H)


def local_adaalter(lr: float = 0.5, eps: float = 1.0, b0: float = 1.0,
                   H: int = 4, warmup_steps: int = 0) -> LocalOptimizer:
    """Algorithm 4 — the paper's main contribution.

    State (per worker):
      b2_sync  : B²_{i,t-t'} — denominator base, ONLY updated at sync rounds,
                 hence identical on all workers at every local step.
      b2_local : A²_{i,t} — running local accumulation B²+Σ G∘G (averaged at sync).
      tprime   : number of local steps since the last sync (t' − 1 before the
                 current step).
      step     : global step count (for warm-up).

    local_step (Alg. 4 lines 4-9):
      t' = tprime + 1
      y  = x − η_t · G / sqrt(b2_sync + t'·ε²·1)
      b2_local += G∘G ;  tprime = t'

    sync (Alg. 4 lines 11-12, after the H-th local_step):
      x        <- mean_workers(x)
      b2_local <- mean_workers(b2_local)
      b2_sync  <- b2_local ;  tprime <- 0
    """

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "tprime": jnp.zeros((), jnp.int32),
            "b2_sync": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
            "b2_local": jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, b0 * b0, jnp.float32), params),
        }

    def local_step(grads, state, params):
        step = state["step"] + 1
        tprime = state["tprime"] + 1
        eta = warmup_lr(lr, step, warmup_steps)
        denom_extra = tprime.astype(jnp.float32) * (eps * eps)
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: p - _cast_like(
                eta * g.astype(jnp.float32) / jnp.sqrt(a + denom_extra), p),
            params, grads, state["b2_sync"])
        b2_local = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)),
            state["b2_local"], grads)
        return new_params, {"step": step, "tprime": tprime,
                            "b2_sync": state["b2_sync"], "b2_local": b2_local}

    def sync(params, state, mean_fn=_tree_mean_identity):
        new_params = mean_fn(params)
        b2 = mean_fn(state["b2_local"])
        return new_params, {"step": state["step"],
                            "tprime": jnp.zeros_like(state["tprime"]),
                            "b2_sync": b2, "b2_local": b2}

    return LocalOptimizer(init, local_step, sync, H)


# --------------------------------------------------------------------------- #
# gradient clipping (wraps any optimizer; cfg.grad_clip)
# --------------------------------------------------------------------------- #
def with_grad_clip(opt, max_norm: float):
    """Global-norm-clip gradients before every update/local_step.

    Works on both levels of the API: for an :class:`Optimizer` the averaged
    gradient is clipped and ``sq_grads`` rescaled by the same factor² (exact
    for the n=1 semantics the synchronous train path uses, where
    ``sq_grads = Ḡ∘Ḡ``); for a :class:`LocalOptimizer` each worker's
    gradient is clipped independently (the wrapper sits under the vmap), so
    the B² accumulators fold in the *clipped* G∘G — the gradient that was
    actually applied. Sync rounds are untouched. ``max_norm <= 0`` returns
    the optimizer unchanged (the documented 'off' value).
    """
    if max_norm <= 0:
        return opt
    if isinstance(opt, LocalOptimizer):
        def local_step(grads, state, params):
            clipped, _ = clip_by_global_norm(grads, max_norm)
            return opt.local_step(clipped, state, params)

        return LocalOptimizer(opt.init, local_step, opt.sync, opt.H)

    def update(grads, sq_grads, state, params):
        clipped, factor = clip_by_global_norm(grads, max_norm)
        sq = jax.tree_util.tree_map(
            lambda s: (s.astype(jnp.float32) * jnp.square(factor)).astype(
                s.dtype), sq_grads)
        return opt.update(clipped, sq, state, params)

    return Optimizer(opt.init, update)


# --------------------------------------------------------------------------- #
# compressed sync (thin shim over the SyncEngine's device-side encode)
# --------------------------------------------------------------------------- #
_RESIDUAL_KEYS = ("res_params", "res_b2")


def compressed_sync(base: LocalOptimizer, compression="int8", *,
                    block: int = 256, use_pallas: bool = False,
                    fused: bool = True) -> LocalOptimizer:
    """Wrap a LocalOptimizer so its sync payload rides a lossy wire codec.

    ``compression`` is a codec name ('bf16', 'int8') or a
    :class:`repro.core.codecs.WireCodec`. Each worker sends
    ``decode(encode(payload + residual))`` — e.g. int8 values plus one fp32
    scale per ``block`` elements (~4x less than fp32), or a bf16 truncation
    (2x) — and keeps the compression error as a per-worker residual (error
    feedback, Stich et al. 2018 style), so the error is re-sent, not lost:

        v          = payload + residual          # fp32
        v̂          = codec.roundtrip(v)          # what the wire carries
        residual'  = v − v̂
        synced     = mean_workers(v̂)

    The numerics live in :func:`repro.core.sync_engine.ef_apply` — this
    wrapper only manages the residual state leaves around the base
    optimizer's sync. With ``fused`` (and an int8 codec) the whole EF chain
    runs as ONE HBM pass per leaf (``kernels/sync_fused.py``) instead of
    three; the two paths are bitwise identical.

    The payload is params (and ``b2_local`` for Local AdaAlter). Local steps
    are untouched — compression only changes the communication rounds. With
    ``compression=''`` (or the lossless 'fp32' codec) the base optimizer is
    returned unchanged, so the uncompressed H=1 path stays bit-identical to
    ``adaalter``.

    State gains two leaves mirroring the param tree: ``res_params`` and (if
    the base tracks accumulators) ``res_b2`` — flat top-level keys so
    ``opt_state_shardings`` places them exactly like the accumulators.
    """
    from repro.core.codecs import get_codec
    from repro.core.sync_engine import ef_apply

    codec = get_codec(compression, block=block, use_pallas=use_pallas,
                      fused=fused)
    if codec.lossless:
        return base

    def _compress(tree, residual, batch_ndim, *, clamp_nonneg: bool = False):
        return ef_apply(tree, residual, codec, batch_ndim,
                        clamp_nonneg=clamp_nonneg)

    def init(params):
        state = base.init(params)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        state["res_params"] = zeros
        if "b2_local" in state:
            state["res_b2"] = jax.tree_util.tree_map(jnp.zeros_like, zeros)
        return state

    def local_step(grads, state, params):
        inner = {k: v for k, v in state.items() if k not in _RESIDUAL_KEYS}
        new_params, new_inner = base.local_step(grads, inner, params)
        for k in _RESIDUAL_KEYS:
            if k in state:
                new_inner[k] = state[k]
        return new_params, new_inner

    def sync(params, state, mean_fn=_tree_mean_identity):
        inner = {k: v for k, v in state.items() if k not in _RESIDUAL_KEYS}
        # In the worker-stacked layout (steps.py: vmapped state, mean over
        # axis 0) every leaf — 'step' included — carries a leading (R,) axis;
        # quantization blocks must then never straddle workers, each of whom
        # sends its own payload. Unstacked state quantizes whole leaves,
        # matching comm.payload_bytes' n/block scales model.
        bnd = 1 if getattr(state["step"], "ndim", 0) > 0 else 0
        wire_p, res_p = _compress(params, state["res_params"], bnd)
        res_b2 = None
        if "res_b2" in state:
            wire_b2, res_b2 = _compress(inner["b2_local"], state["res_b2"],
                                        bnd, clamp_nonneg=True)
            inner = {**inner, "b2_local": wire_b2}
        new_params, new_inner = base.sync(wire_p, inner, mean_fn)
        new_inner["res_params"] = res_p
        if res_b2 is not None:
            new_inner["res_b2"] = res_b2
        return new_params, new_inner

    return LocalOptimizer(init, local_step, sync, base.H)


# --------------------------------------------------------------------------- #
# gradient-staleness anchor (CADA-proper drift statistic)
# --------------------------------------------------------------------------- #
_ANCHOR_KEY = "g_anchor"


def with_grad_anchor(opt: LocalOptimizer) -> LocalOptimizer:
    """Carry a per-worker ``g_anchor`` state leaf: the gradient seen at the
    last sync round, against which the CADA-proper staleness statistic
    ‖g_t − g_anchor‖² is measured (``drift_metric='grad_staleness'``).

    The wrapper only owns the leaf's lifecycle (init to zeros, thread it
    through local_step/sync untouched); *writing* the anchor happens in
    ``launch.steps`` on sync steps, the one place the fresh gradients are in
    scope. A flat top-level key mirroring the param tree, so
    ``opt_state_shardings`` places it exactly like the accumulators.
    """

    def init(params):
        state = opt.init(params)
        state[_ANCHOR_KEY] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    def local_step(grads, state, params):
        inner = {k: v for k, v in state.items() if k != _ANCHOR_KEY}
        new_params, new_inner = opt.local_step(grads, inner, params)
        new_inner[_ANCHOR_KEY] = state[_ANCHOR_KEY]
        return new_params, new_inner

    def sync(params, state, mean_fn=_tree_mean_identity):
        inner = {k: v for k, v in state.items() if k != _ANCHOR_KEY}
        new_params, new_inner = opt.sync(params, inner, mean_fn)
        new_inner[_ANCHOR_KEY] = state[_ANCHOR_KEY]
        return new_params, new_inner

    return LocalOptimizer(init, local_step, sync, opt.H)


# --------------------------------------------------------------------------- #
# factory
# --------------------------------------------------------------------------- #
def make_optimizer(cfg) -> Any:
    """cfg: OptimizerConfig -> Optimizer | LocalOptimizer.

    Assembly order: base algorithm -> ``with_grad_clip`` (clips the gradient
    every worker actually applies) -> ``with_grad_anchor`` (only when the
    adaptive policy watches gradient staleness) -> ``compressed_sync`` (wire
    codec + error feedback on the sync rounds only).
    """
    sync = cfg.sync
    compression = sync.compression
    grad_clip = getattr(cfg, "grad_clip", 0.0)
    if cfg.name in ("sgd", "adagrad", "adaalter"):
        if compression and compression != "fp32":
            # only the sync rounds of local optimizers are compressed;
            # silently ignoring it here would let train_loop report ~4x
            # less comm than actually moves
            raise ValueError(
                f"compression={compression!r} requires a local optimizer "
                f"(local_sgd / local_adaalter), got {cfg.name!r}")
        if cfg.name == "sgd":
            opt = sgd(cfg.lr, cfg.warmup_steps)
        elif cfg.name == "adagrad":
            opt = adagrad(cfg.lr, cfg.eps, cfg.b0, cfg.warmup_steps)
        else:
            opt = adaalter(cfg.lr, cfg.eps, cfg.b0, cfg.warmup_steps)
        return with_grad_clip(opt, grad_clip)
    if cfg.name == "local_sgd":
        opt = local_sgd(cfg.lr, cfg.H, cfg.warmup_steps)
    elif cfg.name == "local_adaalter":
        opt = local_adaalter(cfg.lr, cfg.eps, cfg.b0, cfg.H, cfg.warmup_steps)
    else:
        raise ValueError(f"unknown optimizer {cfg.name!r}")
    opt = with_grad_clip(opt, grad_clip)
    from repro.core.sync_engine import drift_statistic
    if drift_statistic(sync) == "grad_staleness":
        opt = with_grad_anchor(opt)
    if compression:
        opt = compressed_sync(opt, compression, block=sync.block,
                              use_pallas=cfg.use_pallas, fused=sync.fused)
    return opt


def is_local(opt) -> bool:
    return isinstance(opt, LocalOptimizer)
