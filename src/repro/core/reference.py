"""Pure-NumPy line-by-line transcriptions of the paper's Algorithms 1-4.

These deliberately follow the pseudocode *verbatim* (per-worker loops,
explicit synchronization rounds) so tests can assert that the vectorized JAX
implementations are faithful to the paper.
"""
from __future__ import annotations

import numpy as np


def warmup(lr, t, warmup_steps):
    if warmup_steps <= 0:
        return lr
    return lr * min(1.0, t / warmup_steps)


def ref_adagrad(x0, grads, lr, eps, b0=0.0, warmup_steps=0):
    """Algorithm 1. grads: (T, n, d) per-iteration per-worker gradients."""
    T, n, d = grads.shape
    x = x0.astype(np.float64).copy()
    b2 = np.full(d, b0 * b0, np.float64)
    xs = []
    for t in range(1, T + 1):
        G = grads[t - 1].mean(axis=0)                 # line 5
        b2 = b2 + G * G                               # line 6
        x = x - warmup(lr, t, warmup_steps) * G / np.sqrt(b2 + eps * eps)  # line 7
        xs.append(x.copy())
    return np.asarray(xs), b2


def ref_adaalter(x0, grads, lr, eps, b0=1.0, warmup_steps=0):
    """Algorithm 3. grads: (T, n, d)."""
    T, n, d = grads.shape
    x = x0.astype(np.float64).copy()
    b2 = np.full(d, b0 * b0, np.float64)
    xs = []
    for t in range(1, T + 1):
        G = grads[t - 1].mean(axis=0)                                  # line 5
        x = x - warmup(lr, t, warmup_steps) * G / np.sqrt(b2 + eps * eps)  # line 6
        b2 = b2 + (grads[t - 1] ** 2).mean(axis=0)                     # line 7
        xs.append(x.copy())
    return np.asarray(xs), b2


def ref_local_sgd(x0, grads, lr, H, warmup_steps=0):
    """Algorithm 2. grads: (T, n, d); returns per-worker params (T, n, d)."""
    T, n, d = grads.shape
    x = np.tile(x0.astype(np.float64), (n, 1))
    xs = []
    for t in range(1, T + 1):
        y = x - warmup(lr, t, warmup_steps) * grads[t - 1]             # line 5
        if t % H != 0:
            x = y                                                      # line 7
        else:
            x = np.tile(y.mean(axis=0), (n, 1))                        # line 9
        xs.append(x.copy())
    return np.asarray(xs)


def ref_local_adaalter(x0, grads, lr, eps, H, b0=1.0, warmup_steps=0):
    """Algorithm 4. grads: (T, n, d); returns (xs (T,n,d), b2 (n,d))."""
    T, n, d = grads.shape
    x = np.tile(x0.astype(np.float64), (n, 1))
    b2 = np.full((n, d), b0 * b0, np.float64)       # B²_{i,·} (synced base)
    a2 = b2.copy()                                  # A²_{i,·} running local accum
    last_sync_b2 = b2.copy()                        # B²_{i,t-t'}
    xs = []
    for t in range(1, T + 1):
        tp = (t - 1) % H + 1                                            # line 4
        eta = warmup(lr, t, warmup_steps)
        y = x - eta * grads[t - 1] / np.sqrt(last_sync_b2 + tp * eps * eps)  # line 6
        a2 = b2 + grads[t - 1] ** 2                                     # line 7
        if t % H != 0:
            x, b2 = y, a2                                               # line 9
        else:
            x = np.tile(y.mean(axis=0), (n, 1))                         # line 11
            b2 = np.tile(a2.mean(axis=0), (n, 1))                       # line 12
            last_sync_b2 = b2.copy()
        xs.append(x.copy())
    return np.asarray(xs), b2
