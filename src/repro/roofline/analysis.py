"""Three-term roofline from a compiled (dry-run) XLA executable.

CPU containers cannot measure TPU wall time, so the perf report is *derived*
from the compiled artifact:

  compute    = HLO_FLOPs        / peak_FLOPs_per_chip
  memory     = HLO_bytes        / HBM_bandwidth_per_chip
  collective = collective_bytes / ICI_link_bandwidth

``cost_analysis()`` on a GSPMD-partitioned executable reports *per-device*
FLOPs and bytes; likewise the post-partition HLO text contains per-device
shapes, so every term is already per-chip — no division by chip count.

collective_bytes is NOT in cost_analysis: we parse the compiled HLO and sum
the output-shape bytes of every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op. For
all-reduce we charge 2x (reduce-scatter + all-gather wire traffic of a ring
implementation); others are charged at output size. This is a lower bound on
wire bytes (ring chunking overheads ignored) but exact enough to rank
bottlenecks and measure optimization deltas.

MODEL_FLOPS uses the standard 6·N·D estimate (N = params — active params for
MoE — and D = tokens processed); the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat recompute and padding waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

# --------------------------------------------------------------------------- #
# hardware model (TPU v5e)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link
    dcn_bw: float = 6.25e9            # bytes/s per chip, cross-pod
    hbm_bytes: float = 16e9           # HBM capacity per chip


V5E = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO shape literal, e.g. bf16[16,512]{1,0} or f32[] or s32[8]
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# an op definition line: "%name = <shape-or-tuple> opcode(..."
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z0-9-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum per-collective-kind output bytes from (post-SPMD) HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, opcode = m.groups()
        # strip fusion/async wrappers: "all-reduce-start", "all-gather-done"
        base = opcode
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if opcode.endswith("-done"):
            continue                       # counted at -start
        out[base] += _shape_bytes(shape_str)
        counts[base] += 1
    out["__counts__"] = counts  # type: ignore[assignment]
    return out


def collective_wire_bytes(col: Dict[str, int]) -> int:
    """Ring-model wire traffic: all-reduce charged 2x, others 1x."""
    total = 0
    for kind in _COLLECTIVES:
        mult = 2 if kind == "all-reduce" else 1
        total += mult * col.get(kind, 0)
    return total


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float                  # per chip
    hlo_bytes: float                  # per chip (HBM traffic)
    collective_bytes: float           # per chip (wire)
    collectives: Dict[str, int]
    collective_counts: Dict[str, int]
    model_flops_total: float          # 6·N·D, whole job
    bytes_per_device: Optional[float] = None   # from memory_analysis
    hw: Hardware = V5E
    cross_pod_bytes: float = 0.0      # collective bytes crossing the pod axis

    # ---- the three terms, in seconds ---------------------------------- #
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        t = self.collective_bytes / self.hw.ici_bw
        if self.cross_pod_bytes:
            t += self.cross_pod_bytes / self.hw.dcn_bw
        return t

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def model_flops_per_chip(self) -> float:
        return self.model_flops_total / max(self.n_chips, 1)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per chip). >1 => XLA undercounts;
        <1 => remat/recompute/padding waste."""
        if self.hlo_flops == 0:
            return 0.0
        return self.model_flops_per_chip / self.hlo_flops

    @property
    def step_time(self) -> float:
        """Roofline step time (max of the three overlapping terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        if self.step_time == 0:
            return 0.0
        return self.model_flops_per_chip / self.hw.peak_flops / self.step_time

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "cross_pod_bytes": self.cross_pod_bytes,
            "collectives": {k: v for k, v in self.collectives.items()},
            "collective_counts": self.collective_counts,
            "model_flops_total": self.model_flops_total,
            "bytes_per_device": self.bytes_per_device,
            "xla_flops": getattr(self, "xla_flops", None),
            "xla_bytes": getattr(self, "xla_bytes", None),
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu_at_roofline": self.mfu,
        }

    def summary(self) -> str:
        return (f"{self.arch:28s} {self.shape:12s} {self.mesh:10s} "
                f"comp={self.t_compute * 1e3:9.3f}ms "
                f"mem={self.t_memory * 1e3:9.3f}ms "
                f"coll={self.t_collective * 1e3:9.3f}ms "
                f"dom={self.dominant:10s} "
                f"useful={self.useful_flop_ratio:6.3f} "
                f"mfu={self.mfu * 100:5.1f}%")


# --------------------------------------------------------------------------- #
def model_flops(cfg, shape_cfg) -> float:
    """6·N_active·D total FLOPs for the step the shape lowers."""
    n = cfg.active_param_count()
    if shape_cfg.kind == "decode":
        tokens = shape_cfg.global_batch          # one new token per sequence
    else:
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
    mult = 6.0 if shape_cfg.kind == "train" else 2.0
    return mult * n * tokens


def analyze(compiled, *, arch: str, shape_name: str, mesh_name: str,
            n_chips: int, model_flops_total: float,
            hw: Hardware = V5E, pod_axis_chips: int = 0) -> RooflineReport:
    """Build a RooflineReport from a compiled executable.

    FLOPs/bytes/collective bytes come from the trip-count-aware HLO walk in
    :mod:`repro.roofline.hlo_cost` — ``compiled.cost_analysis()`` counts
    ``lax.scan`` bodies once and so undercounts an L-layer scanned model by
    ~L x. The XLA numbers are kept in the record as a cross-check.
    """
    from repro.roofline.hlo_cost import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):                    # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    c = hlo_cost(hlo)
    flops, byts = c.flops, c.bytes
    col = {k: v for k, v in c.coll.items()}
    counts = {k: v for k, v in c.coll_counts.items()}
    wire = collective_wire_bytes(col)

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass

    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=float(wire),
        collectives=col, collective_counts=counts,
        model_flops_total=model_flops_total, bytes_per_device=mem, hw=hw)
    rep.xla_flops = xla_flops            # cross-check (scan bodies counted 1x)
    rep.xla_bytes = xla_bytes
    return rep
