"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import (
    Hardware, RooflineReport, V5E, analyze, collective_wire_bytes,
    model_flops, parse_collectives,
)
from repro.roofline.hlo_cost import RegionCost, region_table

__all__ = ["Hardware", "RooflineReport", "V5E", "analyze",
           "collective_wire_bytes", "model_flops", "parse_collectives",
           "RegionCost", "region_table"]
