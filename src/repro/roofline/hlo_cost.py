"""Trip-count-aware HLO cost model (FLOPs / HBM bytes / collective bytes).

``compiled.cost_analysis()`` counts every computation ONCE — a ``lax.scan``
over 126 transformer layers reports 1/126-th of the real FLOPs (verified
empirically; see EXPERIMENTS.md §Roofline). Since this framework scans
layers precisely to keep 512-device dry-run compiles fast, we walk the
optimized HLO text ourselves:

  * the module is split into named computations, with a module-wide symbol
    table mapping every op name to its result shape (operands are printed
    without shapes in scheduled HLO);
  * ``while`` ops multiply body+condition cost by the loop trip count,
    read from ``backend_config known_trip_count`` (exact — XLA propagates
    it for the counted loops lax.scan emits), falling back to the largest
    integer constant in the condition computation;
  * ``fusion`` ops contribute their callee's FLOPs but only the call-site
    operand/output bytes (fused intermediates never touch HBM);
  * collectives are accumulated per kind and scaled by enclosing trip
    counts — a collective inside the layer scan costs trip x bytes.

FLOPs: dot = 2 * out_elems * contracted_size; convolution =
2 * out_elems * kernel_window; elementwise/reduce = element count
(transcendentals charged 1). Matmuls dominate every architecture here by
orders of magnitude, so flag-op undercounting is immaterial.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_ZERO_FLOP_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "copy",
    "broadcast", "reshape", "transpose", "iota", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "reverse", "gather",
    "scatter", "while", "fusion", "call", "conditional", "custom-call",
    "rng", "rng-bit-generator", "convert", "copy-start", "copy-done",
    "partition-id", "replica-id", "domain", "after-all",
    "optimization-barrier", "send", "recv", "send-done", "recv-done",
    "infeed", "outfeed", "compare", "select", "clamp",
})
_NO_BYTES_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "domain", "optimization-barrier", "partition-id",
    "replica-id", "iota",
})

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_SCALAR_TYPE_RE = re.compile(r"^[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?")
_OPCODE_RE = re.compile(r"^\s*([a-z0-9\-]+)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLEE_ATTR = re.compile(r"(calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_REF_RE = re.compile(r"%([\w.\-]+)")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = byts = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_shape: str
    line: str
    operands_region: str

    @property
    def out_elems(self) -> int:
        return _shape_elems_bytes(self.out_shape)[0]

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.out_shape)[1]

    def operand_refs(self) -> List[str]:
        return _REF_RE.findall(self.operands_region)

    def callees(self) -> List[str]:
        attrs = self.line[len(self.operands_region):]
        out = [m.group(2) for m in _CALLEE_ATTR.finditer(self.line)]
        m = _BRANCHES_RE.search(self.line)
        if m:
            out += [c.strip().lstrip("%") for c in m.group(1).split(",")]
        return out


def _balanced_paren_span(s: str, start: int) -> int:
    """s[start] == '(' -> index just past the matching ')'."""
    depth = 0
    for j in range(start, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(s)


def _parse_op_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """-> (name, out_shape, opcode, operands_region) or None.

    Handles tuple result types containing ``/*index=N*/`` comments, which
    break naive regexes (they contain '=').
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest_i = m.end()
    if rest_i < len(line) and line[rest_i] == "(":
        end = _balanced_paren_span(line, rest_i)
        out_shape = line[rest_i:end]
    else:
        ms = _SCALAR_TYPE_RE.match(line[rest_i:])
        if not ms:
            return None
        end = rest_i + ms.end()
        out_shape = ms.group(0)
    mo = _OPCODE_RE.match(line[end:])
    if not mo:
        return None
    opcode = mo.group(1)
    op_start = end + mo.end() - 1              # index of '('
    op_end = _balanced_paren_span(line, op_start)
    operands = line[op_start + 1:op_end - 1]
    return name, out_shape, opcode, operands


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0, *, bytes_too: bool = True):
        self.flops += mult * other.flops
        if bytes_too:
            self.bytes += mult * other.bytes
        for k in COLLECTIVES:
            self.coll[k] += mult * other.coll[k]
            self.coll_counts[k] += mult * other.coll_counts[k]


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.shape_of: Dict[str, str] = {}       # module-wide symbol table
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for line in text.splitlines():
            stripped = line.strip()
            if current is None:
                if stripped.endswith("{"):
                    m = _HEADER_RE.match(stripped)
                    if m:
                        current = m.group(2)
                        self.computations[current] = []
                        if m.group(1):
                            self.entry = current
                continue
            if stripped == "}" or stripped.startswith("} "):
                current = None
                continue
            parsed = _parse_op_line(line)
            if parsed:
                name, shape, opcode, region = parsed
                op = Op(name, opcode, shape, line.rstrip(), region)
                self.computations[current].append(op)
                self.shape_of[name] = shape

    # ------------------------------------------------------------------ #
    def _operand_bytes(self, op: Op) -> int:
        total = 0
        for ref in op.operand_refs():
            total += _shape_elems_bytes(self.shape_of.get(ref, ""))[1]
        return total

    def _operand_elems(self, op: Op) -> int:
        total = 0
        for ref in op.operand_refs():
            total += _shape_elems_bytes(self.shape_of.get(ref, ""))[0]
        return total

    def _dot_flops(self, op: Op) -> float:
        refs = op.operand_refs()
        lhs_dims = _shape_dims(self.shape_of.get(refs[0], "")) if refs else []
        m = _CDIMS_RE.search(op.line)
        contracted = 1
        if m and lhs_dims:
            for d in (int(x) for x in m.group(1).split(",") if x):
                if d < len(lhs_dims):
                    contracted *= lhs_dims[d]
        return 2.0 * op.out_elems * contracted

    def _conv_flops(self, op: Op) -> float:
        m = re.search(r"size=([0-9x]+)", op.line)
        k = 1
        if m:
            for d in m.group(1).split("x"):
                k *= int(d)
        refs = op.operand_refs()
        cin = 1
        if len(refs) >= 2:
            rhs_dims = _shape_dims(self.shape_of.get(refs[1], ""))
            if len(rhs_dims) >= 2:
                cin = rhs_dims[-2]
        return 2.0 * op.out_elems * k * cin

    def _op_flops(self, op: Op) -> float:
        oc = op.opcode
        if oc == "dot":
            return self._dot_flops(op)
        if oc == "convolution":
            return self._conv_flops(op)
        base = oc[:-6] if oc.endswith("-start") else oc
        if oc in _ZERO_FLOP_OPS or base in COLLECTIVES or oc.endswith("-done"):
            return 0.0
        if oc in ("reduce", "reduce-window"):
            return float(self._operand_elems(op))
        return float(op.out_elems)                 # elementwise

    def _fusion_bytes(self, op: Op) -> float:
        """HBM traffic of one fusion call.

        A fusion that internally dynamic-slices a big operand (the layer
        scan reading one layer's slice of a 48-layer stacked buffer) only
        touches the SLICE, not the buffer — charging the full operand would
        overcount an L-layer scan by ~L x. Likewise a fusion whose root is
        dynamic-update-slice writes the update in place.
        """
        callee_name = next(iter(op.callees()), None)
        callee = self.computations.get(callee_name or "", [])
        params: Dict[int, str] = {}
        for o in callee:
            if o.opcode == "parameter":
                m = re.match(r"\s*(\d+)", o.operands_region)
                if m:
                    params[int(m.group(1))] = o.name
        # map operand position -> consumers of that parameter inside fusion
        refs = op.operand_refs()
        total = 0.0
        for i, ref in enumerate(refs):
            full = _shape_elems_bytes(self.shape_of.get(ref, ""))[1]
            pname = params.get(i)
            if pname and full > (1 << 20):           # only bother for big bufs
                consumers = [o for o in callee
                             if pname in o.operand_refs()]
                if consumers and all(
                        o.opcode in ("dynamic-slice", "slice", "gather")
                        or (o.opcode == "dynamic-update-slice"
                            and o.operand_refs()[:1] == [pname])
                        for o in consumers):
                    sliced = 0.0
                    for o in consumers:
                        if o.opcode == "dynamic-update-slice":
                            upd = o.operand_refs()
                            sliced += _shape_elems_bytes(
                                self.shape_of.get(upd[1], ""))[1] if len(upd) > 1 \
                                else o.out_bytes
                        else:
                            sliced += o.out_bytes
                    total += min(full, sliced)
                    continue
            total += full
        # output: in-place DUS root writes only the update slice
        root = callee[-1] if callee else None
        out_bytes = float(op.out_bytes)
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = root.operand_refs()
            if len(upd) > 1:
                out_bytes = min(out_bytes, 2.0 * _shape_elems_bytes(
                    self.shape_of.get(upd[1], ""))[1])
        return total + out_bytes

    def trip_count(self, op: Op, cond_name: Optional[str]) -> int:
        m = _TRIP_RE.search(op.line)
        if m:
            return int(m.group(1))
        best = 1
        for o in self.computations.get(cond_name or "", []):
            for c in _CONST_RE.finditer(o.line):
                best = max(best, int(c.group(1)))
        return best

    def _while_parts(self, op: Op):
        body = cond = None
        for kind, name in _CALLEE_ATTR.findall(op.line):
            if kind == "body":
                body = name
            elif kind == "condition":
                cond = name
        return body, cond

    # ------------------------------------------------------------------ #
    def _op_cost(self, op: Op) -> Cost:
        """One op's total contribution (recursing into callees) — the unit
        the per-computation walk sums and the per-region attribution
        reports individually."""
        total = Cost()
        oc = op.opcode
        base = oc[:-6] if oc.endswith("-start") else oc
        if base in COLLECTIVES and not oc.endswith("-done"):
            total.coll[base] += op.out_bytes
            total.coll_counts[base] += 1
            total.bytes += op.out_bytes + self._operand_bytes(op)
            return total
        if oc == "fusion":
            for c in op.callees():
                total.add(self.cost(c), bytes_too=False)
            total.bytes += self._fusion_bytes(op)
            return total
        if oc == "while":
            body, cond = self._while_parts(op)
            trip = self.trip_count(op, cond)
            if body:
                total.add(self.cost(body), mult=trip)
            if cond:
                total.add(self.cost(cond), mult=trip)
            return total
        if oc in ("call", "custom-call", "conditional", "async-start"):
            callees = op.callees()
            if oc == "conditional" and callees:
                costs = [self.cost(c) for c in callees]
                total.add(max(costs, key=lambda c: c.flops))
            else:
                for c in callees:
                    total.add(self.cost(c))
            total.bytes += op.out_bytes + self._operand_bytes(op)
            return total
        if oc in _NO_BYTES_OPS:
            return total
        total.flops += self._op_flops(op)
        if oc == "dynamic-update-slice":
            # in-place update: traffic = write + read of the slice only
            refs = op.operand_refs()
            upd = (_shape_elems_bytes(self.shape_of.get(refs[1], ""))[1]
                   if len(refs) > 1 else op.out_bytes)
            total.bytes += 2 * upd
        elif oc in ("dynamic-slice", "slice"):
            total.bytes += 2 * op.out_bytes          # read + write of the slice
        else:
            total.bytes += op.out_bytes + self._operand_bytes(op)
        return total

    def cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        self._memo[comp_name] = total              # break cycles defensively
        for op in self.computations.get(comp_name, []):
            total.add(self._op_cost(op))
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        if not self.entry:
            self.entry = max(self.computations,
                             key=lambda k: len(self.computations[k]))
        return self.cost(self.entry)

    # ------------------------------------------------------------------ #
    def region_costs(self, comp_name: Optional[str] = None
                     ) -> List["RegionCost"]:
        """Per-fused-region cost attribution of one computation (default:
        entry), in program order.

        Post-optimization HLO is a flat sequence of fused regions: every
        entry-level ``fusion`` / ``while`` (the layer scan) / collective /
        ``call``-like op becomes its own region carrying exactly the cost
        the entry walk charges it, and the loose elementwise/reduce ops
        between them are merged into one trailing ``(unfused)`` region —
        so the region list SUMS to :meth:`cost` of the same computation
        (pinned by tests). ``while`` regions record their trip count.
        """
        if comp_name is None:
            if not self.entry:
                self.entry = max(self.computations,
                                 key=lambda k: len(self.computations[k]))
            comp_name = self.entry
        regions: List[RegionCost] = []
        loose = Cost()
        n_loose = 0
        for op in self.computations.get(comp_name, []):
            c = self._op_cost(op)
            if not (c.flops or c.bytes or any(c.coll.values())):
                continue
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            own = (oc in ("fusion", "while", "call", "custom-call",
                          "conditional", "async-start")
                   or base in COLLECTIVES)
            if own:
                trip = 1
                if oc == "while":
                    trip = self.trip_count(op, self._while_parts(op)[1])
                regions.append(RegionCost(
                    name=op.name, opcode=oc, flops=c.flops, bytes=c.bytes,
                    coll_bytes=sum(c.coll.values()), trip=trip))
            else:
                loose.add(c)
                n_loose += 1
        if loose.flops or loose.bytes:
            regions.append(RegionCost(
                name=f"(unfused x{n_loose})", opcode="(unfused)",
                flops=loose.flops, bytes=loose.bytes,
                coll_bytes=sum(loose.coll.values())))
        return regions


@dataclasses.dataclass
class RegionCost:
    """Cost of one entry-level fused region (see ``region_costs``)."""

    name: str
    opcode: str
    flops: float
    bytes: float
    coll_bytes: float = 0.0
    trip: int = 1

    def optimal_s(self, peak_flops: float, hbm_bw: float) -> float:
        """Roofline-optimal seconds: max of the compute and memory times
        (collective bytes are priced by the alpha-beta fabric model, not
        here)."""
        return max(self.flops / peak_flops if peak_flops else 0.0,
                   self.bytes / hbm_bw if hbm_bw else 0.0)


def region_table(hlo_text: str, *, peak_flops: float, hbm_bw: float,
                 top: int = 12) -> Dict[str, object]:
    """JSON-safe per-region cost table of one compiled program — the
    payload ``train --trace`` / ``dryrun --trace`` attach to their spans
    and ``trace.replay`` prices sync overhead from.

    ``regions`` holds the ``top`` most expensive regions by roofline-
    optimal seconds (the tail is summarized in ``dropped_optimal_s``, so
    truncation is visible, never silent); the totals are the FULL
    program's.
    """
    model = HloCostModel(hlo_text)
    regions = model.region_costs()
    rows = [{"region": r.name, "opcode": r.opcode, "trip": r.trip,
             "flops": r.flops, "bytes": r.bytes, "coll_bytes": r.coll_bytes,
             "optimal_s": r.optimal_s(peak_flops, hbm_bw)}
            for r in regions]
    rows.sort(key=lambda r: r["optimal_s"], reverse=True)
    total = model.entry_cost()
    total_opt = max(total.flops / peak_flops if peak_flops else 0.0,
                    total.bytes / hbm_bw if hbm_bw else 0.0)
    kept = rows[:top] if top else rows
    dropped = sum(r["optimal_s"] for r in rows[len(kept):])
    return {"flops": total.flops, "bytes": total.bytes,
            "coll_bytes": sum(total.coll.values()),
            "optimal_s": total_opt, "n_regions": len(rows),
            "dropped_optimal_s": dropped, "regions": kept}


def hlo_cost(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
