"""Trace-driven what-if replay: re-simulate a recorded run under new knobs.

A recorded trace (``trace/events.py``) pins down everything the end-to-end
wall time of a Local AdaAlter run depends on: the measured per-step compute,
the measured host overhead of a sync round, the drift statistic stream the
adaptive policy consumed, and the run's configuration (workers, H, codec,
payload leaves). This module replays that evidence under *substituted* knobs
— fabric bandwidth/latency, worker count, sync period H, adaptive threshold,
codec, flat vs per-leaf collective count — WITHOUT re-running the model, in
the spirit of byteprofile-analysis' replayer (PAPERS.md; dependency-ordered
re-execution against a cost model) reduced to this repo's step-level DAG.

The cost model is STEADY-STATE, per replayed step::

    step_cost = compute + [sync round] (sync_overhead + wire_time)

  compute        the step's own measured duration when it was recorded as a
                 local step (the first one — whose wall is dominated by jit
                 compilation — warm-substituted by the mean of the rest);
                 the warm mean local-step duration when the recorded step
                 was a sync step (its pure-compute part is not separately
                 observable);
  sync_overhead  the steady-state host extra of one sync round (EF encode
                 + the in-process mean). Priced from the recorded HLO
                 per-region cost model when the trace carries one
                 (``meta['hlo_cost']``, written by ``train --trace``):
                 ``compute_est x (sync_optimal_s / local_optimal_s − 1)``
                 — the roofline-optimal ratio of the two compiled
                 programs, anchored to the measured warm local mean, so
                 the device-independent scale cancels. Falls back to
                 warm mean(sync durs) − warm mean(local durs), clamped at
                 >= 0, for traces without HLO costs (hand-built, pre-PR-10)
                 and for all-sync (H=1) recordings where no local sample
                 anchors the ratio. Held at the recorded codec's
                 cost/measurement under codec knobs;
  wire_time      the alpha-beta ``comm.FabricModel.collective_time`` of the
                 round's wire payload under the replay codec / worker count
                 / collective count. The recorded run is an in-process
                 simulation (no real network), so the baseline replay uses
                 wire_time = 0; what-if fabrics attach the modeled term.

One warm model prices every replay, so sweep points are comparable. With no
knobs substituted the replayed wall equals the equally warm-corrected
measured wall *exactly* (the means cancel term-by-term — ``validate``
compares against it and reports the raw sums alongside), and replaying the
recorded policy over the recorded drift stream reproduces the measured sync
schedule bit-for-bit — both are CI gates. The wall tolerance absorbs float
summation order, the degenerate single-sync-round trace (no warm sync
sample exists), and the ``>= 0`` overhead clamp under scheduling noise — a
warm sync mean that dips below the warm local mean reads as zero overhead
rather than a negative one (which would invert the monotone sweep curves),
biasing the baseline prediction up by ``n_sync x`` the few-sample-mean gap.
Replay is pure host arithmetic over the trace: replaying twice is
bit-identical.

Scope note: replayed times are MODELED (alpha-beta fabric + roofline-derived
costs anchored to the measured host walls of the jnp path) — not
Mosaic-true device time. Threshold sweeps need a trace recorded with a
drift-emitting (adaptive) run; fixed_h traces carry no drift stream.

CLI (also the CI perf gate)::

  python -m repro.trace.replay run.trace.json --check --tol 0.1
  python -m repro.trace.replay run.trace.json --workers 32 --H 8 \
      --codec int8 --fabric-defaults
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import comm
from repro.core.sync_policy import (AdaptiveSyncPolicy, FixedHPolicy,
                                    SyncPolicy)
from repro.trace.events import Trace

#: codec names the replay accepts for the ``codec`` knob.
REPLAY_CODECS = ("fp32", "bf16", "int8")


# --------------------------------------------------------------------------- #
# knobs
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ReplayKnobs:
    """What-if substitutions; ``None`` keeps the recorded value.

    ``fabric`` attaches an alpha-beta fabric to the wire term (the recorded
    in-process run has none, so the baseline wire time is zero);
    ``bw_scale`` instead scales the trace's recorded fabric constants
    (ici/dcn bandwidth) — a one-knob "slower interconnect" sweep.
    """

    fabric: Optional[comm.FabricModel] = None
    bw_scale: Optional[float] = None
    n_workers: Optional[int] = None
    H: Optional[int] = None
    sync_policy: Optional[str] = None       # 'fixed_h' | 'adaptive'
    sync_threshold: Optional[float] = None
    h_min: Optional[int] = None
    h_max: Optional[int] = None
    codec: Optional[str] = None
    flat: Optional[bool] = None             # one collective vs per-leaf
    n_shards: Optional[int] = None          # FSDP/TP sub-planes per worker:
                                            # each device's collective moves
                                            # payload/n_shards (sharded flat)
    cross_pod: bool = False

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)      # recurses into the FabricModel
        # report every SET knob — flat=False (--per-leaf) is a real
        # substitution; only unset (None) and the cross_pod default drop out
        out = {k: v for k, v in d.items() if v is not None}
        if not self.cross_pod:
            out.pop("cross_pod", None)
        return out


@dataclasses.dataclass
class ReplayResult:
    """One replayed timeline, summarized."""

    wall_s: float
    compute_s: float
    sync_overhead_s: float
    comm_s: float                 # modeled wire time (0 without a fabric)
    comm_fraction: float          # comm_s / wall_s
    sync_count: int
    sync_steps: List[int]
    steps: int
    n_workers: int
    codec: str
    policy: str
    n_collectives_per_round: int
    round_wire_bytes: float       # full logical payload of one round
    n_shards: int = 1
    round_wire_bytes_per_shard: float = 0.0   # what ONE device's collective
                                              # moves (= payload / n_shards;
                                              # the priced quantity)
    priced_from: str = "warm_means"   # "hlo_regions" when sync_overhead came
                                      # from the recorded per-region HLO costs
    knobs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------- #
# trace -> per-step records
# --------------------------------------------------------------------------- #
def _step_records(trace: Trace) -> List[Dict[str, Any]]:
    """One record per global step: measured dur (max across workers — the
    rendezvous worker), the recorded sync decision, and the drift statistic
    the policy consumed."""
    kind = trace.meta.get("kind", "train")
    if kind != "train":
        # a dryrun trace is a compile/model timeline whose per-pair step
        # indices restart at 0 — replaying it would silently merge
        # unrelated (arch, shape, mesh) pairs into one bogus run
        raise ValueError(f"replay needs a train trace (train --trace); "
                         f"this trace records kind={kind!r}")
    by_step: Dict[int, Dict[str, Any]] = {}
    for s in trace.spans:
        if s.name != "local_step":
            continue
        rec = by_step.setdefault(
            s.step, {"step": s.step, "dur": 0.0,
                     "synced": bool(s.args.get("synced", False)),
                     "drift": float(s.args.get("drift", 0.0))})
        rec["dur"] = max(rec["dur"], s.dur)
    if not by_step:
        raise ValueError("trace contains no local_step spans — was it "
                         "recorded with train --trace?")
    return [by_step[k] for k in sorted(by_step)]


def _mean(xs: Sequence[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


def _warm_anatomy(records: List[Dict[str, Any]]):
    """(local durs, sync durs, warm local, warm sync) — the warm lists drop
    each compiled program's first occurrence (jit-compile-dominated) when a
    second sample exists."""
    local = [r["dur"] for r in records if not r["synced"]]
    syncd = [r["dur"] for r in records if r["synced"]]
    warm_local = local[1:] if len(local) > 1 else local
    warm_sync = syncd[1:] if len(syncd) > 1 else syncd
    return local, syncd, warm_local, warm_sync


def _warm_compute_est(local, syncd, warm_local, warm_sync) -> float:
    """Steady-state per-step compute estimate. An all-sync recording
    (H=1) has no local samples at all — there the sync step IS the step,
    so its warm wall is the estimate (falling back to the raw all-records
    mean would fold the jit-compile wall of step 0 into every replayed
    step and falsely fail the validate gate)."""
    if warm_local:
        return _mean(warm_local)
    if warm_sync:
        return _mean(warm_sync)
    return _mean(local + syncd)


def _hlo_rel_overhead(meta: Dict[str, Any]) -> Optional[float]:
    """Relative sync-step overhead from the recorded HLO per-region costs:
    ``sync_optimal_s / local_optimal_s − 1`` (clamped >= 0), or None when
    the trace carries no usable ``hlo_cost`` meta. Both optimal walls come
    from the same roofline (``roofline.region_table``), so the hardware
    scale cancels — the ratio anchors to the measured warm local mean."""
    hc = meta.get("hlo_cost")
    if not isinstance(hc, dict):
        return None
    try:
        local_s = float(hc["local_step"]["optimal_s"])
        sync_s = float(hc["sync_step"]["optimal_s"])
    except (KeyError, TypeError, ValueError):
        return None
    if not (local_s > 0.0 and sync_s > 0.0):
        return None
    return max(0.0, sync_s / local_s - 1.0)


def _make_policy(meta: Dict[str, Any], knobs: ReplayKnobs) -> SyncPolicy:
    sync = dict(meta.get("sync", {}))
    # a bare H knob means "replay the paper's fixed schedule at that
    # period", even over an adaptive-recorded trace (where H would
    # otherwise only seed the h_max default and silently change nothing)
    name = knobs.sync_policy or (
        "fixed_h" if knobs.H is not None
        else sync.get("policy", "fixed_h") or "fixed_h")
    H = int(knobs.H if knobs.H is not None else meta.get("H", 1))
    if name == "fixed_h":
        return FixedHPolicy(max(1, H))
    if name == "adaptive":
        thr = (knobs.sync_threshold if knobs.sync_threshold is not None
               else float(sync.get("threshold", 0.0)))
        h_min = int(knobs.h_min if knobs.h_min is not None
                    else sync.get("h_min", 1) or 1)
        h_max = int(knobs.h_max if knobs.h_max is not None
                    else sync.get("h_max", 0) or 4 * max(1, H))
        return AdaptiveSyncPolicy(threshold=thr, h_min=max(1, h_min),
                                  h_max=max(h_max, h_min, 1))
    raise ValueError(f"unknown sync_policy {name!r}")


def _schedule(trace: Trace, knobs: ReplayKnobs,
              records: List[Dict[str, Any]]) -> Tuple[List[int], str]:
    """Re-derive the sync schedule host-side from the recorded drift stream
    (no model run). With recorded knobs this reproduces the measured
    schedule exactly — the policy sees the identical inputs."""
    meta = trace.meta
    policy = _make_policy(meta, knobs)
    start = int(meta.get("start_step", 0))
    policy.reset(start)
    schedule_knobs = (knobs.H, knobs.sync_policy, knobs.sync_threshold,
                      knobs.h_min, knobs.h_max)
    if all(k is None for k in schedule_knobs):
        ss = meta.get("sync_state0")
        if ss:           # resume the mid-window state the run restored into
            policy.load_host_state(int(ss["since"]), float(ss["drift"]))
    for rec in records:
        want = policy.want_sync(rec["step"])
        policy.observe(rec["step"], want, {"drift": rec["drift"]})
    return list(policy.sync_steps), policy.name


# --------------------------------------------------------------------------- #
# the replay
# --------------------------------------------------------------------------- #
def _resolve_fabric(meta: Dict[str, Any],
                    knobs: ReplayKnobs) -> Optional[comm.FabricModel]:
    base = knobs.fabric
    if base is None and knobs.bw_scale is not None:
        base = comm.FabricModel(**meta.get("fabric", {}))
    if base is not None and knobs.bw_scale is not None:
        base = base.scaled(knobs.bw_scale)    # scales an explicit fabric too
    return base


def replay(trace: Trace, knobs: ReplayKnobs = ReplayKnobs()) -> ReplayResult:
    """Re-simulate the recorded timeline's critical path under ``knobs``."""
    meta = trace.meta
    records = _step_records(trace)
    algorithm = meta.get("algorithm", "local_adaalter")
    n_params = int(meta.get("n_params", 0))
    sync = dict(meta.get("sync", {}))
    block = int(sync.get("block", 256))
    codec = knobs.codec if knobs.codec is not None \
        else (sync.get("compression", "") or "fp32")
    if codec not in REPLAY_CODECS:
        raise ValueError(f"unknown replay codec {codec!r} "
                         f"(expected one of {REPLAY_CODECS})")
    n_workers = int(knobs.n_workers if knobs.n_workers is not None
                    else meta.get("n_workers", 1))
    flat = bool(knobs.flat if knobs.flat is not None
                else meta.get("flat", False))
    n_leaves = int(meta.get("n_payload_leaves", 1))
    n_coll = comm.round_collectives(algorithm, n_leaves, flat=flat)

    # measured anatomy of the recorded run — STEADY-STATE (warm): each
    # compiled program's first occurrence is excluded from the estimates
    # (its wall is dominated by jit compilation, and a what-if schedule
    # must charge new sync rounds the steady-state cost — a 5 s compile
    # charged per replayed round would swamp the sweep curves on short
    # recorded runs). The same warm model prices EVERY replay, so sweep
    # points stay comparable; ``validate`` holds the baseline against the
    # equally compile-corrected measured wall, where the means cancel and
    # the prediction is exact by construction.
    local_durs, sync_durs, warm_local, warm_sync = _warm_anatomy(records)
    compute_est = _warm_compute_est(local_durs, sync_durs, warm_local,
                                    warm_sync)
    # sync overhead: prefer the recorded HLO per-region cost model — the
    # roofline-optimal sync/local ratio anchored to the warm local mean.
    # This is program-structure-derived (deterministic), not a noisy
    # difference of two measured means, which is what lets the validate
    # gate run at a tighter tolerance. Requires a local anchor: on an
    # all-sync (H=1) recording compute_est already IS the warm sync mean,
    # and adding a ratio-priced extra on top would double-charge the round.
    rel = _hlo_rel_overhead(meta)
    if rel is not None and warm_local:
        sync_overhead = rel * compute_est
        priced_from = "hlo_regions"
    else:
        sync_overhead = max(0.0, _mean(warm_sync) - compute_est) \
            if warm_sync else 0.0
        priced_from = "warm_means"

    # the what-if schedule, from the recorded drift stream
    sync_steps, policy_name = _schedule(trace, knobs, records)

    # modeled wire time of one round under the knob fabric. With a sharded
    # flat plane (n_shards > 1) each device's worker-axis collective moves
    # only its sub-plane, so the alpha-beta model is charged the per-shard
    # payload, not the full plane (recorded in meta by train --trace; the
    # --shards knob sweeps it).
    fabric = _resolve_fabric(meta, knobs)
    n_shards = max(1, int(knobs.n_shards if knobs.n_shards is not None
                          else meta.get("n_shards", 1)))
    round_bytes = comm.sync_payload_bytes(algorithm, n_params,
                                          compression=codec, block=block)
    shard_bytes = round_bytes / n_shards
    wire_time = (fabric.collective_time(shard_bytes, n_coll, n_workers,
                                        cross_pod=knobs.cross_pod)
                 if fabric is not None else 0.0)

    n_sync = len(sync_steps)
    # recorded local steps keep their own measured walls (the first one
    # warm-substituted); recorded sync steps contribute the warm compute
    # estimate (their pure-compute part is not separately observable);
    # every replayed round pays the warm measured sync overhead + the
    # modeled wire transfer
    compute_s = (sum(warm_local) + (len(local_durs) - len(warm_local) +
                                    len(sync_durs)) * compute_est)
    overhead_s = n_sync * sync_overhead
    comm_s = n_sync * wire_time
    wall = compute_s + overhead_s + comm_s
    return ReplayResult(
        wall_s=wall, compute_s=compute_s, sync_overhead_s=overhead_s,
        comm_s=comm_s, comm_fraction=(comm_s / wall if wall else 0.0),
        sync_count=n_sync, sync_steps=sync_steps, steps=len(records),
        n_workers=n_workers, codec=codec, policy=policy_name,
        n_collectives_per_round=n_coll, round_wire_bytes=round_bytes,
        n_shards=n_shards, round_wire_bytes_per_shard=shard_bytes,
        priced_from=priced_from, knobs=knobs.to_dict())


# --------------------------------------------------------------------------- #
# validation (the CI perf gate)
# --------------------------------------------------------------------------- #
#: default predicted/measured wall tolerance — generous vs the exact-by-
#: construction baseline, so the gate only trips on real model drift.
DEFAULT_TOL = 0.1


def validate(trace: Trace, tol: float = DEFAULT_TOL) -> Dict[str, Any]:
    """Baseline replay vs the measurement it was derived from.

    Gates (``ok``): the replayed wall of the *recorded* configuration is
    within ``tol`` of the *warm-corrected* measured wall (the summed step
    spans with each compiled program's first, jit-compile-dominated
    occurrence replaced by its steady-state mean — the replay models
    steady-state cost, so both sides of the comparison must), and the
    replayed sync schedule equals the measured one exactly. The raw summed
    spans and the loop's own wall are reported alongside.

    On a trace without HLO costs the prediction is exact by construction
    (warm means cancel) and the gate only trips on model drift. On a trace
    WITH ``hlo_cost`` meta the sync overhead is priced from the compiled
    programs' roofline ratio instead of the measured mean, so the gate
    genuinely tests the cost model against measurement — which is what
    licenses running it at a tighter tolerance (``priced_from`` in the
    returned dict says which mode applied).
    """
    records = _step_records(trace)
    local, syncd, warm_local, warm_sync = _warm_anatomy(records)
    measured_span_wall = sum(local) + sum(syncd)
    est_l = _warm_compute_est(local, syncd, warm_local, warm_sync)
    est_s = _mean(warm_sync)
    measured_warm_wall = (
        sum(warm_local) + (len(local) - len(warm_local)) * est_l
        + sum(warm_sync) + (len(syncd) - len(warm_sync)) * est_s)
    res = replay(trace, ReplayKnobs())
    measured = trace.meta.get("measured", {})
    m_count = measured.get("sync_count")
    m_steps = measured.get("sync_steps")
    if m_count is None:       # fall back to the per-span decisions
        m_steps = [r["step"] for r in records if r["synced"]]
        m_count = len(m_steps)
    ratio = (res.wall_s / measured_warm_wall if measured_warm_wall
             else float("nan"))
    sync_ok = (res.sync_count == int(m_count)
               and (m_steps is None or res.sync_steps == list(m_steps)))
    return {
        "predicted_wall_s": res.wall_s,
        "measured_warm_wall_s": measured_warm_wall,
        "measured_span_wall_s": measured_span_wall,
        "measured_loop_wall_s": measured.get("wall_s"),
        "ratio": ratio,
        "tol": tol,
        "wall_ok": bool(abs(ratio - 1.0) <= tol),
        "measured_sync_count": int(m_count),
        "replayed_sync_count": res.sync_count,
        "sync_count_ok": bool(sync_ok),
        "priced_from": res.priced_from,
        "ok": bool(abs(ratio - 1.0) <= tol and sync_ok),
    }


# --------------------------------------------------------------------------- #
# sweeps — the paper's Figure-1/2-style curves from ONE recorded run
# --------------------------------------------------------------------------- #
def sweep_workers(trace: Trace, workers: Sequence[int] = (1, 2, 4, 8, 16, 32),
                  fabric: Optional[comm.FabricModel] = None,
                  base: ReplayKnobs = ReplayKnobs()) -> List[Dict[str, Any]]:
    """Comm fraction vs worker count (Fig. 1's shape) under one fabric."""
    fabric = fabric or comm.FabricModel(**trace.meta.get("fabric", {}))
    rows = []
    for n in workers:
        r = replay(trace, dataclasses.replace(base, fabric=fabric,
                                              n_workers=int(n)))
        rows.append({"workers": int(n), "wall_s": r.wall_s,
                     "comm_s": r.comm_s, "comm_fraction": r.comm_fraction,
                     "sync_count": r.sync_count})
    return rows


def sweep_H(trace: Trace, Hs: Sequence[int] = (1, 2, 4, 8, 16),
            fabric: Optional[comm.FabricModel] = None,
            base: ReplayKnobs = ReplayKnobs()) -> List[Dict[str, Any]]:
    """Wall/speedup vs sync period H (Fig. 2's shape): fixed_h replay of
    the same recorded compute under each period."""
    fabric = fabric or comm.FabricModel(**trace.meta.get("fabric", {}))
    rows = []
    base_wall = None
    for H in Hs:
        r = replay(trace, dataclasses.replace(
            base, fabric=fabric, H=int(H), sync_policy="fixed_h"))
        if base_wall is None:
            base_wall = r.wall_s
        rows.append({"H": int(H), "wall_s": r.wall_s, "comm_s": r.comm_s,
                     "comm_fraction": r.comm_fraction,
                     "sync_count": r.sync_count,
                     "speedup_vs_first": (base_wall / r.wall_s
                                          if r.wall_s else float("nan"))})
    return rows


def sweep_codecs(trace: Trace, codecs: Sequence[str] = REPLAY_CODECS,
                 fabric: Optional[comm.FabricModel] = None,
                 base: ReplayKnobs = ReplayKnobs()) -> List[Dict[str, Any]]:
    """Wire-volume/wall vs sync codec under one fabric."""
    fabric = fabric or comm.FabricModel(**trace.meta.get("fabric", {}))
    rows = []
    for c in codecs:
        r = replay(trace, dataclasses.replace(base, fabric=fabric, codec=c))
        rows.append({"codec": c, "wall_s": r.wall_s, "comm_s": r.comm_s,
                     "comm_fraction": r.comm_fraction,
                     "round_wire_bytes": r.round_wire_bytes,
                     "sync_count": r.sync_count})
    return rows


# --------------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------------- #
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="recorded trace JSON (train --trace)")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: baseline replay must match the "
                         "measurement (wall within --tol, sync schedule "
                         "exactly); exit 1 otherwise")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--H", type=int, default=None)
    ap.add_argument("--policy", default=None, choices=["fixed_h", "adaptive"])
    ap.add_argument("--threshold", type=float, default=None)
    ap.add_argument("--codec", default=None, choices=list(REPLAY_CODECS))
    ap.add_argument("--flat", dest="flat", action="store_true", default=None,
                    help="replay the sync round as ONE collective")
    ap.add_argument("--per-leaf", dest="flat", action="store_false",
                    help="replay the sync round as per-leaf collectives")
    ap.add_argument("--shards", type=int, default=None,
                    help="FSDP/TP sub-planes per worker: price each "
                         "device's collective at payload/shards (defaults "
                         "to the trace's recorded n_shards)")
    ap.add_argument("--bw-scale", type=float, default=None,
                    help="scale the recorded fabric bandwidths (implies a "
                         "modeled fabric)")
    ap.add_argument("--fabric-defaults", action="store_true",
                    help="attach the trace's recorded FabricModel to the "
                         "wire term (the baseline replay models none)")
    ap.add_argument("--cross-pod", action="store_true")
    args = ap.parse_args()

    trace = Trace.load(args.trace)
    if args.check:
        v = validate(trace, tol=args.tol)
        print(json.dumps(v, indent=1))
        if not v["ok"]:
            raise SystemExit(1)
        return
    fabric = (comm.FabricModel(**trace.meta.get("fabric", {}))
              if args.fabric_defaults else None)
    knobs = ReplayKnobs(fabric=fabric, bw_scale=args.bw_scale,
                        n_workers=args.workers, H=args.H,
                        sync_policy=args.policy,
                        sync_threshold=args.threshold, codec=args.codec,
                        flat=args.flat, n_shards=args.shards,
                        cross_pod=args.cross_pod)
    print(json.dumps(replay(trace, knobs).to_dict(), indent=1))


if __name__ == "__main__":
    main()
