"""Step-level timeline recorder: structured spans on one monotonic clock.

The paper's headline claim is wall-clock (up to 30% less training time once
communication stops being the bottleneck), but a ``TrainResult`` only says
how long the whole run took — not *where* the time went. This module records
a run as a stream of structured spans, one timeline row per worker, that the
Chrome exporter (``trace/chrome.py``) renders in Perfetto and the replay
engine (``trace/replay.py``) re-simulates under substituted knobs.

Span kinds (``SPAN_KINDS``):

  local_step   one compiled train-step call, host-measured (the span covers
               dispatch *and* the blocking metric read, so device work is
               inside it). Carries the sync decision the ``SyncEngine``
               actually took: ``synced``, the window position ``sync_since``
               and accumulated ``sync_drift`` at decision time, and the
               per-step drift statistic ``drift`` the adaptive policy
               consumed — everything the replay engine needs to re-derive
               the schedule without re-running the model. Under
               ``--metrics``/``--trace`` instrumentation the step span also
               carries the health numbers the metrics registry exports —
               ``grad_norm`` (raw-grad L2) and the per-bucket ``b2``
               quantile summary (:func:`health_span_args`) — plus
               ``hlo_optimal_s``, the roofline-optimal wall of the step's
               compiled program from the per-region HLO cost walk
               (``roofline.region_table``). All of these are plain ``args``
               entries: no schema change, lossless round-trip.
  ef_encode    the device-side error-feedback encode of one sync round —
               MODELED (``SyncEngine.modeled_encode_hbm_bytes`` over the
               roofline HBM bandwidth), since a CPU host cannot time the
               TPU-side pass. ``hlo_extra_optimal_s`` (when present) is the
               HLO-derived roofline extra of the sync-step program over the
               local-step program — the cost-model view of the same encode.
  collective   the wire transfer of one sync round — MODELED by the
               alpha-beta ``comm.FabricModel.collective_time`` (the
               in-process simulation moves no real bytes). Carries the
               codec, wire bytes and collective count (per-leaf vs flat).
  ckpt         one checkpoint save, host-measured.
  eval         host-side metric bookkeeping/logging, host-measured.

All host times share ONE clock — ``time.perf_counter`` (monotonic;
``time.time`` jumps under clock adjustment), rebased so ``t0 == 0`` at the
first span. Modeled spans are flagged ``modeled=True`` and are laid out
*after* the step span that produced them; their timestamps are bookkeeping
for the timeline view, their durations are the model.

The JSON schema (``Trace.to_dict``) is versioned and lossless: spans
round-trip through ``save``/``load`` and through the Chrome exporter
bit-identically (``tests/test_trace.py``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

#: the span vocabulary — new kinds require a schema version bump.
SPAN_KINDS = ("local_step", "ef_encode", "collective", "ckpt", "eval")

#: bump when the JSON layout changes shape (not when meta grows keys).
SCHEMA_VERSION = 1


def to_jsonable(x: Any) -> Any:
    """Strict-JSON encode: tag non-finite floats (a supported
    ``--sync-threshold inf`` lands in the meta) instead of letting
    ``json.dump`` emit the non-RFC ``Infinity`` literal Perfetto and
    ``chrome://tracing`` reject. Inverse: :func:`from_jsonable`."""
    if isinstance(x, float) and not math.isfinite(x):
        return {"__nonfinite__": "inf" if x > 0 else
                "-inf" if x < 0 else "nan"}
    if isinstance(x, dict):
        return {k: to_jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [to_jsonable(v) for v in x]
    return x


def from_jsonable(x: Any) -> Any:
    if isinstance(x, dict):
        if set(x) == {"__nonfinite__"}:
            return float(x["__nonfinite__"])
        return {k: from_jsonable(v) for k, v in x.items()}
    if isinstance(x, list):
        return [from_jsonable(v) for v in x]
    return x


@dataclasses.dataclass
class Span:
    """One timed interval on one worker's timeline row.

    ``t0``/``dur`` are seconds on the trace's rebased perf_counter clock.
    ``modeled`` marks durations that come from the fabric/roofline models
    rather than a host measurement. ``args`` is free-form JSON-safe detail
    (loss, drift, codec, wire bytes, ...).
    """

    name: str
    worker: int
    step: int
    t0: float
    dur: float
    modeled: bool = False
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "worker": self.worker, "step": self.step,
                "t0": self.t0, "dur": self.dur, "modeled": self.modeled,
                "args": dict(self.args)}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Span":
        return Span(name=d["name"], worker=int(d["worker"]),
                    step=int(d["step"]), t0=float(d["t0"]),
                    dur=float(d["dur"]), modeled=bool(d["modeled"]),
                    args=dict(d.get("args", {})))


@dataclasses.dataclass
class Trace:
    """A recorded run: metadata + the span stream, JSON round-trippable."""

    meta: Dict[str, Any]
    spans: List[Span]

    @property
    def workers(self) -> List[int]:
        return sorted({s.worker for s in self.spans})

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def to_dict(self) -> Dict[str, Any]:
        return {"version": SCHEMA_VERSION, "meta": dict(self.meta),
                "spans": [s.to_dict() for s in self.spans]}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Trace":
        v = d.get("version")
        if v != SCHEMA_VERSION:
            raise ValueError(f"trace schema version {v!r} != {SCHEMA_VERSION}")
        return Trace(meta=dict(d.get("meta", {})),
                     spans=[Span.from_dict(s) for s in d.get("spans", [])])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(to_jsonable(self.to_dict()), f, indent=1,
                      allow_nan=False)

    @staticmethod
    def load(path: str) -> "Trace":
        with open(path) as f:
            return Trace.from_dict(from_jsonable(json.load(f)))


def health_span_args(summary: Dict[str, Any]) -> Dict[str, Any]:
    """The subset of a ``obs.health.SyncHealthProbe.step_summary`` that
    belongs on the step span: ``grad_norm`` and the per-bucket ``b2``
    quantile summary. The trace and the metrics registry are fed from the
    SAME summary dict, so the two exports report the same numbers (drift
    already rides the span as the replay engine's input; the sync-round
    residual/MSE probes stay registry-only — they describe the round, not
    the step). Values are already plain floats (JSON-safe, lossless
    round-trip through save/load and the Chrome exporter)."""
    out: Dict[str, Any] = {}
    if "grad_norm" in summary:
        out["grad_norm"] = summary["grad_norm"]
    if "b2" in summary:
        out["b2"] = {name: dict(qs) for name, qs in summary["b2"].items()}
    return out


class TraceRecorder:
    """Builds a :class:`Trace` while a run executes.

    All timestamps come from :meth:`now` — ``time.perf_counter`` rebased to
    the recorder's first call — so every span shares one monotonic clock
    (the train loop's own wall measurement uses the same source).
    """

    def __init__(self, meta: Optional[Dict[str, Any]] = None) -> None:
        self.meta: Dict[str, Any] = dict(meta or {})
        self.spans: List[Span] = []
        self._origin: Optional[float] = None

    # ---------------- clock ---------------------------------------------- #
    def now(self) -> float:
        t = time.perf_counter()
        if self._origin is None:
            self._origin = t
        return t - self._origin

    # ---------------- recording ------------------------------------------ #
    def add(self, name: str, *, worker: int = 0, step: int = -1,
            t0: float, dur: float, modeled: bool = False,
            **args: Any) -> Span:
        if name not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {name!r} "
                             f"(expected one of {SPAN_KINDS})")
        span = Span(name=name, worker=worker, step=step, t0=t0, dur=dur,
                    modeled=modeled, args=args)
        self.spans.append(span)
        return span

    @contextmanager
    def span(self, name: str, *, worker: int = 0, step: int = -1,
             **args: Any) -> Iterator[Dict[str, Any]]:
        """Host-measured span context; the yielded dict lets the body attach
        args computed inside the interval. The partial interval is recorded
        even when the body raises (a crash is exactly when the timeline
        matters)."""
        t0 = self.now()
        try:
            yield args
        finally:
            self.add(name, worker=worker, step=step, t0=t0,
                     dur=self.now() - t0, **args)

    # ---------------- finalize -------------------------------------------- #
    def freeze(self) -> Trace:
        return Trace(meta=dict(self.meta), spans=list(self.spans))

    def save(self, path: str) -> None:
        self.freeze().save(path)
