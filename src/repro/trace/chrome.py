"""Lossless Chrome ``trace_event`` export: open a recorded run in Perfetto.

``to_chrome`` maps the span stream onto the Trace Event Format that
``chrome://tracing`` / https://ui.perfetto.dev render natively:

  * one *process* per worker (``pid`` = worker id, named "worker N"),
  * one *thread row* per span kind within it (compiled steps, modeled
    device encode, modeled wire, checkpoints, host bookkeeping),
  * complete ``"X"`` events in microseconds,
  * and one *flow arrow* per sync round — from each worker's step span into
    its ``collective`` span — so the rendezvous the all-reduce imposes reads
    as converging arrows across the worker rows.

The export is LOSSLESS: every ``"X"`` event embeds its source span verbatim
under ``args.span`` and the trace meta rides in ``otherData``, so
``from_chrome(to_chrome(t))`` reconstructs the exact :class:`Trace`
(span order included) — pinned by ``tests/test_trace.py``.

CLI:  python -m repro.trace.chrome run.trace.json -o run.chrome.json
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from repro.trace.events import (SCHEMA_VERSION, SPAN_KINDS, Span, Trace,
                                from_jsonable, to_jsonable)

#: stable thread row per span kind (Perfetto sorts rows by tid).
_TIDS = {name: i for i, name in enumerate(SPAN_KINDS)}
_TID_LABELS = {
    "local_step": "steps (measured)",
    "ef_encode": "EF encode (modeled)",
    "collective": "wire (modeled)",
    "ckpt": "checkpoint",
    "eval": "host bookkeeping",
}


def to_chrome(trace: Trace) -> Dict[str, Any]:
    """Trace -> Chrome trace_event JSON object (``traceEvents`` + metadata)."""
    events: List[Dict[str, Any]] = []
    for w in trace.workers:
        events.append({"ph": "M", "name": "process_name", "pid": w,
                       "args": {"name": f"worker {w}"}})
        for kind, tid in _TIDS.items():
            events.append({"ph": "M", "name": "thread_name", "pid": w,
                           "tid": tid,
                           "args": {"name": _TID_LABELS[kind]}})

    for i, s in enumerate(trace.spans):
        events.append({
            "name": s.name, "ph": "X",
            "pid": s.worker, "tid": _TIDS[s.name],
            "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
            "cat": "modeled" if s.modeled else "measured",
            # the verbatim span (plus its stream position) makes the export
            # lossless — from_chrome() rebuilds the Trace from these alone
            # (strict-JSON encoded: Perfetto rejects Infinity/NaN literals)
            "args": {"span": to_jsonable(s.to_dict()), "span_index": i},
        })

    # flow arrows: step -> its sync round's wire transfer, per worker.
    # Sources resolve in STREAM order (most recent step span for the
    # (worker, step) key) — dryrun traces restart step indices per
    # (arch, shape, mesh) pair, so a global dict would key-collide across
    # pairs and anchor arrows on the wrong pair's span.
    steps: Dict[Any, Span] = {}
    n_flow = 0
    for s in trace.spans:
        if s.name == "local_step":
            steps[(s.worker, s.step)] = s
            continue
        if s.name != "collective":
            continue
        src = steps.get((s.worker, s.step))
        if src is None:
            continue
        fid = f"sync-{s.step}-w{s.worker}-{n_flow}"
        n_flow += 1
        events.append({"ph": "s", "name": "sync_round", "cat": "sync",
                       "id": fid, "pid": src.worker,
                       "tid": _TIDS["local_step"],
                       "ts": (src.t0 + src.dur) * 1e6})
        events.append({"ph": "f", "name": "sync_round", "cat": "sync",
                       "id": fid, "bp": "e", "pid": s.worker,
                       "tid": _TIDS["collective"], "ts": s.t0 * 1e6})

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema_version": SCHEMA_VERSION,
                          "meta": to_jsonable(dict(trace.meta))}}


def from_chrome(doc: Dict[str, Any]) -> Trace:
    """Inverse of :func:`to_chrome` — exact span stream + meta back."""
    other = doc.get("otherData", {})
    v = other.get("schema_version")
    if v != SCHEMA_VERSION:
        raise ValueError(f"trace schema version {v!r} != {SCHEMA_VERSION}")
    indexed = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        if "span" not in args:
            raise ValueError(f"X event without embedded span: {ev['name']!r}")
        indexed.append((int(args["span_index"]),
                        Span.from_dict(from_jsonable(args["span"]))))
    indexed.sort(key=lambda p: p[0])
    return Trace(meta=from_jsonable(dict(other.get("meta", {}))),
                 spans=[s for _, s in indexed])


def export(trace_path: str, chrome_path: str) -> Dict[str, Any]:
    doc = to_chrome(Trace.load(trace_path))
    with open(chrome_path, "w") as f:
        json.dump(doc, f, allow_nan=False)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="recorded trace JSON (train --trace)")
    ap.add_argument("-o", "--out", default="",
                    help="Chrome trace path (default: <trace>.chrome.json)")
    args = ap.parse_args()
    out = args.out or (args.trace.rsplit(".json", 1)[0] + ".chrome.json")
    doc = export(args.trace, out)
    print(f"wrote {out} ({len(doc['traceEvents'])} events) — open in "
          f"chrome://tracing or https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
