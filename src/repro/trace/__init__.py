"""Trace subsystem: record where a run's time goes, replay it under what-ifs.

  events.py   TraceRecorder — structured spans (local_step / ef_encode /
              collective / ckpt / eval) on one perf_counter clock, with
              modeled device/wire costs attached from roofline + comm;
  chrome.py   lossless Chrome trace_event export (Perfetto: workers as
              rows, sync rounds as flow arrows);
  replay.py   trace-driven what-if engine — re-simulate the recorded
              critical path under substituted fabric / workers / H /
              threshold / codec / collective-count knobs, and the CI gate
              that pins predicted-vs-measured wall and sync schedule.
"""
from repro.trace.events import (SCHEMA_VERSION, SPAN_KINDS, Span, Trace,
                                TraceRecorder)

#: chrome/replay are ALSO `python -m` entrypoints — importing them eagerly
#: here would re-execute them under runpy (RuntimeWarning), so they load
#: lazily on attribute access. The `replay` FUNCTION is deliberately not
#: re-exported here: importing the submodule binds the package attribute
#: `repro.trace.replay` to the MODULE, so a same-named function alias would
#: silently change type after the first access — use
#: ``repro.trace.replay.replay`` (or import from the submodule).
_LAZY = {
    "from_chrome": "chrome", "to_chrome": "chrome",
    "DEFAULT_TOL": "replay", "REPLAY_CODECS": "replay",
    "ReplayKnobs": "replay", "ReplayResult": "replay",
    "sweep_H": "replay", "sweep_codecs": "replay",
    "sweep_workers": "replay", "validate": "replay",
}

__all__ = ["SCHEMA_VERSION", "SPAN_KINDS", "Span", "Trace", "TraceRecorder",
           *sorted(_LAZY)]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.trace.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
