"""Deterministic synthetic LM data with non-IID worker shards.

The paper's setting (§3) has *different local datasets* on each worker:
``D_i != D_j`` and, in general, ``E_{z_i}∇f(x;z_i) != E_{z_j}∇f(x;z_j)``.
The 1B-Word corpus is not available offline, so we substitute a *learnable*
synthetic language: a noisy bigram (Markov) process whose transition table is
a fixed pseudo-random permutation, mixed with Zipf-distributed unigram noise.

* Learnability: the permutation bigram is exactly representable by one
  embedding->logits layer, so cross-entropy falls from log(V) toward the
  noise floor ``H(noise)`` — convergence curves are meaningful.
* Non-IID-ness: each worker ``w`` uses a *different* permutation (derived from
  ``seed + w``) for a ``non_iid_frac`` fraction of positions, so worker
  gradients have genuinely different expectations, matching the paper's
  assumption (tested in tests/test_data.py).
* Determinism: batch ``(worker, step)`` is a pure function of
  ``(seed, worker, step)`` — restarts and data-parallel re-sharding reproduce
  the exact stream with no state files.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


def _permutation(vocab_size: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).permutation(vocab_size)


def _zipf_probs(vocab_size: int, a: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


@dataclasses.dataclass
class SyntheticLM:
    """Noisy-bigram synthetic language, sharded non-IID across workers."""

    vocab_size: int
    seq_len: int
    n_workers: int = 1
    seed: int = 0
    non_iid: bool = True
    noise: float = 0.1            # prob. of a Zipf-noise token (entropy floor)
    non_iid_frac: float = 0.5     # fraction of steps driven by the worker table
    zipf_a: float = 1.2

    def __post_init__(self):
        self._shared = _permutation(self.vocab_size, self.seed)
        self._worker_tables = [
            _permutation(self.vocab_size, self.seed + 7919 * (w + 1))
            if self.non_iid else self._shared
            for w in range(self.n_workers)
        ]
        self._zipf = _zipf_probs(self.vocab_size, self.zipf_a)

    # ------------------------------------------------------------------ #
    def worker_batch(self, worker: int, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        """(batch_size, seq_len) tokens + next-token labels for one worker."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + worker * 65_537 + step) % (2**63))
        table = self._worker_tables[worker % max(self.n_workers, 1)]
        S, V = self.seq_len, self.vocab_size
        seq = np.empty((batch_size, S + 1), dtype=np.int64)
        seq[:, 0] = rng.integers(0, V, size=batch_size)
        # Pre-draw the per-position mode: 0 shared-bigram, 1 worker-bigram, 2 noise
        u = rng.random((batch_size, S))
        use_noise = u < self.noise
        use_worker = (~use_noise) & (u < self.noise + (1 - self.noise) * self.non_iid_frac)
        noise_draws = rng.choice(V, size=(batch_size, S), p=self._zipf)
        for t in range(S):
            cur = seq[:, t]
            nxt = np.where(use_worker[:, t], table[cur], self._shared[cur])
            seq[:, t + 1] = np.where(use_noise[:, t], noise_draws[:, t], nxt)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def global_batch(self, step: int, global_batch: int,
                     *, with_worker_axis: bool = True) -> Dict[str, np.ndarray]:
        """Batch for all workers: (R, B/R, S) if with_worker_axis else (B, S)."""
        R = max(self.n_workers, 1)
        assert global_batch % R == 0, (global_batch, R)
        per = global_batch // R
        parts = [self.worker_batch(w, step, per) for w in range(R)]
        out = {k: np.stack([p[k] for p in parts]) for k in parts[0]}
        if not with_worker_axis:
            out = {k: v.reshape(global_batch, -1) for k, v in out.items()}
        return out

    def entropy_floor(self) -> float:
        """Per-token cross-entropy of the true process (nats) — the loss floor."""
        p_noise = self.noise
        h_zipf = -np.sum(self._zipf * np.log(self._zipf))
        # mixture over {deterministic bigram, noise}; non-IID split between two
        # permutations looks like a 2-way mixture to a worker-agnostic model.
        h_det = 0.0
        if self.non_iid and self.non_iid_frac > 0:
            f = self.non_iid_frac
            h_det = -(f * np.log(f) + (1 - f) * np.log(1 - f))
        h = (-(1 - p_noise) * np.log(1 - p_noise + 1e-12)
             - p_noise * np.log(p_noise + 1e-12)
             + (1 - p_noise) * h_det + p_noise * h_zipf)
        return float(h)


def make_train_batch(cfg, shape_cfg, dataset: SyntheticLM, step: int,
                     *, n_workers: int = 0) -> Dict[str, np.ndarray]:
    """Full train batch for an architecture: tokens/labels + modality stubs."""
    if n_workers:
        batch = dataset.global_batch(step, shape_cfg.global_batch,
                                     with_worker_axis=True)
        lead = (n_workers, shape_cfg.global_batch // n_workers)
    else:
        batch = dataset.global_batch(step, shape_cfg.global_batch,
                                     with_worker_axis=False)
        lead = (shape_cfg.global_batch,)
    rng = np.random.default_rng((dataset.seed * 9_973 + step) % (2**63))
    if getattr(cfg, "cross_attn_every", 0):
        batch["image_embeds"] = (rng.standard_normal(
            lead + (cfg.n_image_tokens, cfg.d_model)) * 0.02).astype(np.float32)
    if getattr(cfg, "is_encdec", False):
        batch["audio_frames"] = (rng.standard_normal(
            lead + (shape_cfg.seq_len, cfg.d_model)) * 0.02).astype(np.float32)
    return batch
