"""Data pipeline: deterministic synthetic non-IID LM streams (see synthetic.py)."""
from repro.data.synthetic import SyntheticLM, make_train_batch

__all__ = ["SyntheticLM", "make_train_batch"]
