"""End-to-end driver: train a ~100M-param model for a few hundred steps.

The model is the paper's Big LSTM family at a width where the embedding +
softmax + 2 LSTMP layers land near 100M parameters (the paper's own model is
~1B because of its 793k-word vocabulary). Local AdaAlter (H=4) with warm-up,
checkpointing every 50 steps, restartable.

NOTE: a few hundred steps of a 100M model is hours of CPU time in this
container; the default --steps 300 is the assignment's ask, use --steps 5
for a quick verification (the smoke tests do exactly that).

  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse

from repro.configs import ModelConfig, OptimizerConfig, ShapeConfig
from repro.launch.train import train_loop
from repro.models.counting import count_params


def make_100m_lstm() -> ModelConfig:
    # 2 LSTMP layers d=2048/proj 512 (the paper's real width) + 75k vocab
    # x 512 embed + full softmax = ~96M params: laptop-trainable.
    return ModelConfig(
        name="biglstm-100m", family="lstm", n_layers=2, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=75000, lstm_proj=512,
        act="", param_dtype="float32",
        source="LSTM-2048-512 of Jozefowicz et al. (paper's model), scaled")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--H", type=int, default=4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = make_100m_lstm()
    print(f"{cfg.name}: {count_params(cfg):,} params")
    shape = ShapeConfig(name="e2e", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    opt = OptimizerConfig(name="local_adaalter", lr=0.5, H=args.H,
                          warmup_steps=min(100, args.steps // 3))
    res = train_loop(cfg, shape, opt, steps=args.steps,
                     checkpoint_dir=args.checkpoint_dir, checkpoint_every=50,
                     log_every=10)
    print(f"final loss {res.final_loss:.4f} after {res.steps} steps "
          f"({res.wall_s:.0f}s)")


if __name__ == "__main__":
    main()
