"""Reproduce the paper's Figure 3 / Table 2 structure at CPU scale.

Trains the paper's own architecture family (Big LSTM, reduced) on the
synthetic non-IID LM stream with each algorithm the paper compares:

  * Distributed AdaGrad  (Alg. 1)  — fully synchronous baseline
  * Distributed AdaAlter (Alg. 3)  — same comm, new accumulator ordering
  * Local AdaAlter       (Alg. 4)  — H in {4, 8, 16}

and reports final train PPL together with the *simulated* wall-clock per
epoch from the paper's own time model (compute + amortized comm on the v5e
fabric constants). The paper's claims reproduced here:

  1. AdaAlter tracks AdaGrad's convergence (Table 2: 44.36 vs 44.58 PPL);
  2. Local AdaAlter matches at equal epochs with less time (Fig. 3);
  3. larger H -> more time saved but worse PPL (Table 2 trend).

  PYTHONPATH=src python examples/reproduce_paper.py [--steps 150]
"""
import argparse

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.comm import FabricModel, step_time
from repro.launch.train import train_loop
from repro.models.counting import count_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--workers", type=int, default=8,
                    help="simulated worker count for the time model (paper: 8)")
    args = ap.parse_args()

    cfg = reduced(get_arch("biglstm"), vocab=512)
    shape = ShapeConfig(name="paper", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    n_params = count_params(cfg)
    # time model: measured single-step compute stands in for the paper's GPU
    # step; comm from the v5e fabric constants. Only RATIOS matter.
    fabric = FabricModel()
    compute_s = 0.1

    runs = [("adagrad", 1), ("adaalter", 1),
            ("local_adaalter", 4), ("local_adaalter", 8),
            ("local_adaalter", 16)]
    print(f"{'method':20s} {'H':>3s} {'final loss':>11s} {'final PPL':>10s} "
          f"{'sim step (ms)':>14s} {'epoch time vs AdaGrad':>22s}")
    t_base = None
    for name, H in runs:
        opt = OptimizerConfig(name=name, lr=0.5, H=H, warmup_steps=50)
        res = train_loop(cfg, shape, opt, steps=args.steps, verbose=False)
        t = step_time(name, n_params, compute_s, args.workers, H, fabric)
        t_base = t_base or t
        print(f"{name:20s} {H:3d} {res.final_loss:11.4f} "
              f"{min(res.ppl[-1], 1e6):10.2f} {t * 1e3:14.2f} "
              f"{100 * t / t_base:21.1f}%")
    print("\npaper claim: Local AdaAlter reaches comparable PPL with ~30% "
          "less wall time; larger H saves more time at slightly worse PPL.")


if __name__ == "__main__":
    main()
