"""Quickstart: train a tiny model with Local AdaAlter (paper Alg. 4).

Runs in ~1 minute on CPU. Shows the three-line public API:
config -> train_loop -> metrics, plus the communication accounting that is
the paper's whole point (2/H of fully-synchronous AdaGrad).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.configs import OptimizerConfig, ShapeConfig, get_arch, reduced
from repro.core.comm import sync_bytes_per_step
from repro.launch.train import train_loop
from repro.models.counting import count_params


def main():
    cfg = reduced(get_arch("qwen2-7b"), n_layers=2, d_model=128, vocab=256)
    shape = ShapeConfig(name="tiny", seq_len=64, global_batch=8, kind="train")
    n_params = count_params(cfg)
    print(f"model: {cfg.name} ({n_params:,} params)")

    for name, H in [("adagrad", 1), ("local_adaalter", 4)]:
        opt = OptimizerConfig(name=name, lr=0.5, H=H, warmup_steps=20)
        res = train_loop(cfg, shape, opt, steps=40, verbose=False)
        comm = sync_bytes_per_step(name, n_params, H)
        print(f"{name:16s} H={H}  final loss {res.final_loss:7.4f}  "
              f"avg comm/step {comm / 1e6:6.2f} MB "
              f"({'%.0f%% of sync AdaGrad' % (100 * comm / (4 * n_params))})")


if __name__ == "__main__":
    sys.exit(main())
