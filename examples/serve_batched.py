"""End-to-end serving driver: batched requests against a small model.

Builds a reduced member of an assigned architecture family (default: the
hybrid attn+SSM hymba — the interesting decode path), prefs a batch of
prompts and greedy-decodes continuations, demonstrating the full
prefill -> KV-cache/recurrent-state -> decode_step pipeline that the
``decode_32k`` / ``long_500k`` dry-run shapes lower.

  PYTHONPATH=src python examples/serve_batched.py --arch hymba-1.5b
"""
import argparse

from repro.configs import get_arch, reduced
from repro.launch.serve import serve_session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    print(f"serving {cfg.name} ({cfg.family}) — batch={args.batch}, "
          f"prompt={args.prompt_len}, new={args.new_tokens}")
    gen, tps = serve_session(cfg, batch=args.batch,
                             prompt_len=args.prompt_len,
                             new_tokens=args.new_tokens)
    print(f"{tps:.1f} tok/s; generations:")
    for i, row in enumerate(gen):
        print(f"  request {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
