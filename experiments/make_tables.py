"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run records."""
import glob
import json
import os
import sys

D = os.environ.get("DRYRUN_DIR") or os.path.join(os.path.dirname(__file__), "dryrun_baseline_v2")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def main(mesh_filter="single"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(D, "*.json"))):
        with open(fn) as f:
            res = json.load(f)
        if mesh_filter == "single" and not fn.endswith("_single.json"):
            continue
        if mesh_filter == "multi" and not fn.endswith("_multi.json"):
            continue
        for rec in res["records"]:
            rows.append(rec)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r.get("variant", "")))
    print("| arch | shape | variant | t_comp (ms) | t_mem (ms) | t_coll (ms) "
          "| dominant | useful | MFU@roofline | AR bytes/chip | AG bytes/chip |")
    print("|---|---|---|---:|---:|---:|---|---:|---:|---:|---:|")
    for r in rows:
        coll = r.get("collectives", {})
        print(f"| {r['arch']} | {r['shape']} | {r.get('variant','')} "
              f"| {r['t_compute_s']*1e3:,.1f} | {r['t_memory_s']*1e3:,.1f} "
              f"| {r['t_collective_s']*1e3:,.1f} | {r['dominant']} "
              f"| {r['useful_flop_ratio']:.3f} | {r['mfu_at_roofline']*100:.1f}% "
              f"| {fmt_bytes(coll.get('all-reduce'))} "
              f"| {fmt_bytes(coll.get('all-gather'))} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "single")
